"""Workload fingerprinting + online retuning for the serve engine.

The paper's scalability guarantee is about *workloads*, not just systems:
a winner tuned offline against one request mix goes stale the moment the
live mix drifts.  This module closes that loop for the continuous
runtime, in three pieces the engine composes per generation:

* ``WorkloadWindow`` — a sliding window of what the engine actually
  observed: admissions (arrival step, prompt length, generation budget,
  how much of each prompt repeats recently-seen prompts), queue depth per
  step, and draft-acceptance outcomes.  Every statistic is counted in
  *decode steps*, never wall-clock, so the whole retuning loop is
  deterministic (same trace ⇒ same fingerprints ⇒ same retune step).
* ``WorkloadFingerprint`` — the window reduced to the signature the
  tuner keys on: arrival rate, prompt/generation length distribution,
  demand depth, prefix-share fraction and the MEASURED draft acceptance
  rate (``nan`` until any draft or probe ran — no data is not 0.0).
  ``fingerprint_sig`` quantizes it into the cache's workload-signature
  key component; ``fingerprint_distance`` is the shift metric.
* ``OnlineRetuner`` — the shift detector + warm-started retune policy:
  when the live fingerprint drifts past ``threshold`` from the signature
  the active config was tuned under, it re-tunes the (frozen) serve knob
  space against surrogate params rebuilt from the *measured* fingerprint
  (``params_for_fingerprint``: the measured acceptance rate replaces the
  stale ``spec_accept`` constant), seeding the tuner with the active
  config and the nearest-signature cached winner instead of starting
  cold, and persists the new winner under the fingerprint's signature.

Import discipline matches ``repro.serve.space``: numpy-only at import
time (the engine talks to this module, never the other way around), with
the autotune cache imported lazily inside the methods that touch it.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.params import Config, ParameterSpace
from repro.core.tuner import Tuner

from .space import CotuneParams, ServeSurrogate, params_for_fingerprint

__all__ = [
    "WorkloadFingerprint",
    "WorkloadWindow",
    "OnlineRetuner",
    "fingerprint_sig",
    "parse_sig",
    "fingerprint_distance",
    "nearest_workload",
    "coerce_config",
]


@dataclass(frozen=True)
class WorkloadFingerprint:
    """The live request window reduced to what the tuner keys on.

    All fields are measured by the engine (``WorkloadWindow``), none are
    assumed: ``accept_rate`` in particular is the real per-token draft
    acceptance (or the 1-token n-gram probe's hit rate when speculation
    is off) — ``nan`` means *no draft data yet*, which consumers must
    treat as "keep the prior", never as an acceptance of zero.
    """

    arrival_rate: float   # admissions per decode step over the window
    prompt_mean: float    # mean prompt length of windowed admissions
    prompt_spread: float  # relative prompt-length spread (std / mean)
    gen_mean: float       # mean requested generation budget
    depth: float          # mean queued+resident demand per step
    share_frac: float     # mean fraction of each prompt covering a
    #                       recently-seen prompt's prefix (sharing's win)
    accept_rate: float    # measured draft acceptance; nan = no data


# signature quantization: one letter per field, alphabetical, so the
# string is canonical; floats at 2 decimals, lengths/depth at integers
_SIG_FIELDS: Tuple[Tuple[str, str, str], ...] = (
    ("a", "arrival_rate", "f"),
    ("d", "depth", "i"),
    ("g", "gen_mean", "i"),
    ("p", "prompt_mean", "i"),
    ("r", "prompt_spread", "f"),
    ("s", "share_frac", "f"),
    ("x", "accept_rate", "f"),
)


def fingerprint_sig(fp: WorkloadFingerprint) -> str:
    """Quantized canonical string form, e.g.
    ``a0.50_d12_g8_p24_r0.35_s0.30_x0.60`` (``x?`` while acceptance has
    no data) — the cache key's workload-signature component."""
    parts = []
    for tag, name, kind in _SIG_FIELDS:
        v = float(getattr(fp, name))
        if math.isnan(v):
            parts.append(f"{tag}?")
        elif kind == "i":
            parts.append(f"{tag}{int(round(v))}")
        else:
            parts.append(f"{tag}{v:.2f}")
    return "_".join(parts)


def parse_sig(sig: str) -> Optional[WorkloadFingerprint]:
    """Inverse of ``fingerprint_sig`` (up to quantization).  ``None`` for
    anything that is not a workload signature — the generic ``"-"``
    component of offline/migrated cache entries included."""
    fields: Dict[str, float] = {}
    try:
        for part in str(sig).split("_"):
            tag, raw = part[:1], part[1:]
            fields[tag] = float("nan") if raw == "?" else float(raw)
    except (ValueError, IndexError):
        return None
    if sorted(fields) != [t for t, _, _ in _SIG_FIELDS]:
        return None
    return WorkloadFingerprint(
        **{name: fields[tag] for tag, name, _ in _SIG_FIELDS})


def _rel(a: float, b: float) -> float:
    """Relative gap in [0, 1]: |a-b| / max(a, b) (0 when both ~0)."""
    m = max(abs(a), abs(b))
    return abs(a - b) / m if m > 1e-12 else 0.0


def fingerprint_distance(a: WorkloadFingerprint,
                         b: WorkloadFingerprint) -> float:
    """Shift metric between two fingerprints: the mean of per-field
    normalized gaps (relative for rates/lengths/depth, absolute for the
    already-relative spread/share/accept fields).  The acceptance field
    is skipped while either side has no data — absence of draft evidence
    must not read as a workload shift."""
    comps = [
        _rel(a.arrival_rate, b.arrival_rate),
        _rel(a.prompt_mean, b.prompt_mean),
        _rel(a.gen_mean, b.gen_mean),
        _rel(a.depth, b.depth),
        abs(a.prompt_spread - b.prompt_spread),
        abs(a.share_frac - b.share_frac),
    ]
    if math.isfinite(a.accept_rate) and math.isfinite(b.accept_rate):
        comps.append(abs(a.accept_rate - b.accept_rate))
    return float(sum(comps) / len(comps))


def nearest_workload(candidates: Dict[str, Dict[str, Any]],
                     fp: WorkloadFingerprint, radius: float
                     ) -> Optional[Tuple[str, Dict[str, Any], float]]:
    """The cached entry whose workload signature lies nearest ``fp``
    within ``radius`` — the transfer lookup that replaces exact-key miss.

    Signature-less entries (the generic ``"-"`` of offline winners and
    migrated pre-signature entries) sit AT the radius: eligible as the
    fallback seed, but any parseable nearer signature beats them.  Ties
    break on sorted signature order, so transfer is deterministic.
    """
    best: Optional[Tuple[float, str]] = None
    for ws in sorted(candidates):
        parsed = parse_sig(ws)
        d = radius if parsed is None else fingerprint_distance(fp, parsed)
        if d <= radius and (best is None or d < best[0]):
            best = (d, ws)
    if best is None:
        return None
    d, ws = best
    return ws, candidates[ws], d


def coerce_config(space: ParameterSpace, config: Config) -> Config:
    """Snap a prior winner onto ``space``: unknown keys drop, missing
    keys default, out-of-domain values land on the nearest valid choice
    (via the unit-cube round trip).  Warm-start seeds come from other
    tuning contexts — a deployed ``prefill_chunk`` of 512 must seed a
    48-token window's space as its largest choice, not explode."""
    out: Config = {}
    for p in space:
        v = config.get(p.name, p.default)
        if p.validate(v):
            out[p.name] = v
            continue
        try:
            out[p.name] = p.from_unit(p.to_unit(v))
        except Exception:
            out[p.name] = p.default
    fixed = getattr(space, "fixed", None)
    if fixed:
        out.update(fixed)
    return out


class WorkloadWindow:
    """Sliding window of the engine's live workload observations.

    ``capacity`` bounds the admission records (and the recent-prompt set
    the share estimate matches against); draft outcomes and queue depths
    keep their own step-bounded windows.  Everything is O(capacity) per
    record — the window rides the serve loop's host side.
    """

    def __init__(self, capacity: int = 16, prefix_cap: int = 64,
                 step_window: int = 64):
        if capacity < 1:
            raise ValueError("window capacity must be >= 1")
        self.capacity = capacity
        self.prefix_cap = prefix_cap
        # (arrival step, prompt_len, gen_budget, share_estimate)
        self._reqs: deque = deque(maxlen=capacity)
        self._prompts: deque = deque(maxlen=capacity)
        self._drafts: deque = deque(maxlen=step_window)  # (proposed, hits)
        self._depths: deque = deque(maxlen=step_window)

    @property
    def n_requests(self) -> int:
        return len(self._reqs)

    def record_request(self, step: int, prompt: Sequence[int],
                       max_new: int) -> None:
        """One admission: length stats plus a config-independent share
        estimate — the longest common prefix against the recent prompts,
        as a fraction of this prompt (capped at ``prefix_cap`` tokens so
        the estimate stays O(capacity * prefix_cap)).  Measured from
        content, not from the sharing machinery, so the fingerprint sees
        a shareable workload even while ``share_prefix`` is off."""
        head = list(prompt[:self.prefix_cap])
        best = 0
        for prev in self._prompts:
            n = 0
            for x, y in zip(prev, head):
                if x != y:
                    break
                n += 1
            if n > best:
                best = n
        denom = max(1, min(len(prompt), self.prefix_cap))
        self._reqs.append((int(step), len(prompt), int(max_new),
                           best / denom))
        self._prompts.append(head)

    def record_draft(self, proposed: int, accepted: int) -> None:
        """One dispatch's draft outcome — real speculative verify counts
        when ``draft_len > 0``, the engine's 1-token n-gram probe when
        speculation is off (both measure per-token acceptance)."""
        if proposed > 0:
            self._drafts.append((int(proposed), int(accepted)))

    def record_depth(self, depth: int) -> None:
        """Queued + resident demand at one loop step."""
        self._depths.append(int(depth))

    def fingerprint(self, step: int) -> Optional[WorkloadFingerprint]:
        """The window reduced at loop step ``step`` (None while empty)."""
        if not self._reqs:
            return None
        steps, plens, gens, shares = zip(*self._reqs)
        span = max(1, int(step) - steps[0] + 1)
        pmean = sum(plens) / len(plens)
        if len(plens) > 1 and pmean > 0:
            var = sum((x - pmean) ** 2 for x in plens) / len(plens)
            spread = math.sqrt(var) / pmean
        else:
            spread = 0.0
        proposed = sum(d for d, _ in self._drafts)
        accepted = sum(h for _, h in self._drafts)
        depth = (sum(self._depths) / len(self._depths)
                 if self._depths else float(len(self._reqs)))
        return WorkloadFingerprint(
            arrival_rate=len(self._reqs) / span,
            prompt_mean=pmean,
            prompt_spread=spread,
            gen_mean=sum(gens) / len(gens),
            depth=depth,
            share_frac=sum(shares) / len(shares),
            accept_rate=(accepted / proposed if proposed > 0
                         else float("nan")),
        )


class OnlineRetuner:
    """Shift detector + warm-started retune policy for the serve loop.

    ``maybe_retune`` is called at the engine's step boundary: every
    ``check_every`` steps it fingerprints the window and, when the
    distance to the signature the active config was tuned under exceeds
    ``threshold`` (and the ``cooldown`` since the last retune elapsed),
    runs a ``budget``-test tune of the frozen serve knob space against
    surrogate params rebuilt from the measured fingerprint — seeded with
    the active config and the nearest-signature cached winner
    (``transfer_radius`` bounds how far transfer reaches).  The winner is
    persisted under the fingerprint's signature and becomes the new
    baseline; the returned event carries everything the engine needs to
    swap knobs and everything tests need to audit the decision.

    Deterministic end to end: step-counted trigger, seeded tuner,
    sorted-signature transfer ties.
    """

    def __init__(self, space: ParameterSpace, base_params: CotuneParams,
                 *, baseline: Any = None, budget: int = 16,
                 threshold: float = 0.25, min_requests: int = 6,
                 cooldown: int = 32, check_every: int = 4,
                 optimizer: str = "rrs", seed: int = 0,
                 batch: Optional[bool] = None,
                 active_config: Optional[Config] = None,
                 sig_dims: Optional[Dict[str, int]] = None,
                 dtype: str = "float32", cache: Any = None,
                 transfer_radius: float = 0.75, mesh: str = ""):
        if isinstance(baseline, str):
            baseline = parse_sig(baseline)
        self.space = space
        self.base_params = base_params
        self.baseline: Optional[WorkloadFingerprint] = baseline
        self.budget = int(budget)
        self.threshold = float(threshold)
        self.min_requests = int(min_requests)
        self.cooldown = int(cooldown)
        self.check_every = max(1, int(check_every))
        self.optimizer = optimizer
        self.seed = int(seed)
        self.batch = batch
        self.active_config = (coerce_config(space, active_config)
                              if active_config else None)
        self.sig_dims = dict(sig_dims) if sig_dims else None
        self.dtype = dtype
        self.cache = cache
        self.transfer_radius = float(transfer_radius)
        # device-topology signature the engine runs at (autotune.mesh_sig;
        # "" = legacy single-device).  Winners persist AND transfer-scan
        # at this mesh only — a config tuned for a 4-way TP engine must
        # never warm-start a single-device loop as if it were native.
        self.mesh = str(mesh)
        self.n_retunes = 0
        self.tests_spent = 0
        self.events: List[Dict[str, Any]] = []
        self._next_check = 0
        self._last_retune: Optional[int] = None

    # ------------------------------------------------------------------
    def _candidates(self) -> Dict[str, Dict[str, Any]]:
        """Cached serve winners at this model shape, keyed by workload
        signature (empty without ``sig_dims`` — nothing to key on)."""
        if self.sig_dims is None:
            return {}
        from repro import autotune

        cache = self.cache or autotune.default_cache()
        return cache.scan_workloads(
            autotune.SERVE_SYSTEM,
            autotune.shape_sig({k: int(v)
                                for k, v in self.sig_dims.items()}),
            self.dtype, autotune.backend_name(), mesh=self.mesh)

    def _persist(self, sig: str, config: Config, value: float,
                 n_tests: int, step: int) -> None:
        if self.sig_dims is None:
            return
        from repro import autotune

        autotune.put_serve_config(
            self.sig_dims, self.dtype, config, value,
            cache=self.cache, workload=sig, mesh=self.mesh,
            meta={"source": "online_retune", "step": int(step),
                  "n_tests": int(n_tests)})

    # ------------------------------------------------------------------
    def maybe_retune(self, window: WorkloadWindow,
                     step: int) -> Optional[Dict[str, Any]]:
        """The engine's per-step hook.  Returns the retune event (with
        the winning knobs under ``"config"``) or None."""
        if step < self._next_check:
            return None
        self._next_check = step + self.check_every
        if window.n_requests < self.min_requests:
            return None
        fp = window.fingerprint(step)
        if fp is None:
            return None
        if self.baseline is None:
            # no tuned signature on record: anchor on the first full
            # window instead of treating "unknown" as "shifted"
            self.baseline = fp
            return None
        dist = fingerprint_distance(fp, self.baseline)
        if dist <= self.threshold:
            return None
        if (self._last_retune is not None
                and step - self._last_retune < self.cooldown):
            return None
        return self.retune(fp, step=step, distance=dist)

    def retune(self, fp: WorkloadFingerprint, *, step: int = 0,
               distance: float = float("inf")) -> Dict[str, Any]:
        """Warm-started retune against the measured fingerprint."""
        sig = fingerprint_sig(fp)
        params = params_for_fingerprint(fp, self.base_params)
        seeds: List[Config] = []
        if self.active_config:
            seeds.append(self.active_config)
        warm_source = "cold"
        near = nearest_workload(self._candidates(), fp,
                                self.transfer_radius)
        if near is not None:
            ws, entry, d = near
            seeds.append(coerce_config(self.space, entry["config"]))
            warm_source = ("exact" if ws == sig
                           else f"near({ws}@{d:.2f})")
        report = Tuner(self.space, ServeSurrogate(params),
                       budget=self.budget, optimizer=self.optimizer,
                       seed=self.seed, batch=self.batch,
                       warm_start=seeds or None).run()
        winner = dict(report.best_config)
        self._persist(sig, winner, report.best_metric.value,
                      report.n_tests, step)
        self.baseline = fp
        self.active_config = winner
        self._last_retune = int(step)
        self.n_retunes += 1
        self.tests_spent += report.n_tests
        event = {
            "step": int(step),
            "distance": float(distance),
            "signature": sig,
            "fingerprint": {name: float(getattr(fp, name))
                            for _, name, _ in _SIG_FIELDS},
            "config": winner,
            "value": float(report.best_metric.value),
            "n_tests": int(report.n_tests),
            "warm_source": warm_source,
            # the surrogate constant the retune actually used vs the
            # engine's measurement — the bench's ±0.1 acceptance gate
            "spec_accept": float(params.spec_accept),
            "measured_accept": float(fp.accept_rate),
        }
        self.events.append(event)
        return event
