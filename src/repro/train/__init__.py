"""Training substrate: step factories, knobs, fault-tolerant loop."""
from .loop import SimulatedFailure, TrainLoopConfig, train
from .step import RunKnobs, init_train_state, make_serve_step, make_train_step

__all__ = [n for n in dir() if not n.startswith("_")]
