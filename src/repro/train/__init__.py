"""Training substrate: step factories, knobs, fault-tolerant loop.

The knob-space side (``repro.train.space``) is numpy-only — tuners that
only need the space should import it directly and skip this package's
eager jax imports.
"""
from .loop import SimulatedFailure, TrainLoopConfig, train
from .space import apply_train_knobs, train_knob_space
from .step import RunKnobs, init_train_state, make_serve_step, make_train_step

__all__ = [n for n in dir() if not n.startswith("_")]
