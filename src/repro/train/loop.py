"""Fault-tolerant training loop.

Features scaled down from the 1000-node design to this container:

* auto-resume from the newest valid checkpoint (params + optimizer + step),
* atomic periodic checkpoints (async optional),
* restart-safe data (batches are a pure function of the step),
* simulated failure injection (tests kill the loop mid-run and resume),
* elastic restore onto a different mesh (shardings arg of restore),
* per-step metrics with throughput accounting.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ModelConfig
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import Model
from repro.optim import OptimizerConfig
from repro.train.step import RunKnobs, init_train_state, make_train_step

__all__ = ["TrainLoopConfig", "SimulatedFailure", "train"]


class SimulatedFailure(RuntimeError):
    """Raised by failure injection; tests treat it as a node crash."""


@dataclass
class TrainLoopConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    ckpt_async: bool = False
    fail_at_step: Optional[int] = None  # failure injection (tests)
    opt: OptimizerConfig = field(default_factory=OptimizerConfig)
    knobs: RunKnobs = field(default_factory=lambda: RunKnobs(loss_chunk=0))


def train(cfg: ModelConfig, loop: TrainLoopConfig,
          callbacks: Optional[List[Callable[[int, Dict], None]]] = None
          ) -> Dict[str, Any]:
    model = Model(cfg)
    data = SyntheticLMDataset(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=loop.seq_len,
        global_batch=loop.global_batch, seed=loop.seed))

    params, opt_state = init_train_state(
        model, jax.random.PRNGKey(loop.seed), loop.knobs)

    manager = None
    start_step = 0
    if loop.ckpt_dir:
        manager = CheckpointManager(loop.ckpt_dir, keep=loop.ckpt_keep,
                                    async_save=loop.ckpt_async)
        if manager.latest() is not None:
            start_step, state = manager.restore(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(model, loop.opt, loop.knobs),
                      donate_argnums=(0, 1) if loop.knobs.donate else ())

    history: List[Dict[str, float]] = []
    tokens_per_step = loop.seq_len * loop.global_batch
    t_start = time.time()
    step = start_step
    try:
        for step in range(start_step, loop.steps):
            if loop.fail_at_step is not None and step == loop.fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            if cfg.frontend or cfg.encoder:
                rng = np.random.default_rng(loop.seed * 7919 + step)
                batch["frontend_embeds"] = jnp.asarray(rng.normal(
                    size=(loop.global_batch, cfg.frontend_tokens,
                          cfg.frontend_dim)).astype(np.float32))
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_seconds"] = time.time() - t0
            metrics["tokens_per_sec"] = tokens_per_step / max(
                metrics["step_seconds"], 1e-9)
            history.append(metrics)
            if callbacks:
                for cb in callbacks:
                    cb(step, metrics)
            if loop.log_every and (step + 1) % loop.log_every == 0:
                print(f"[train] step {step + 1}/{loop.steps} "
                      f"loss={metrics['loss']:.4f} "
                      f"acc={metrics['accuracy']:.3f} "
                      f"tok/s={metrics['tokens_per_sec']:.0f}")
            if manager and (step + 1) % loop.ckpt_every == 0:
                manager.save(step + 1, {"params": params, "opt": opt_state},
                             extra={"loss": metrics["loss"]})
    finally:
        if manager:
            manager.wait()

    if manager and (step + 1) % loop.ckpt_every != 0:
        manager.save(step + 1, {"params": params, "opt": opt_state})
        manager.wait()

    return {
        "params": params,
        "opt_state": opt_state,
        "history": history,
        "final_step": step + 1,
        "wall_seconds": time.time() - t_start,
    }
