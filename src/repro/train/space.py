"""Train-step knobs as an ACTS ``ParameterSpace``.

The training runtime's execution knobs (``repro.train.step.RunKnobs``)
exposed to the tuner stack: microbatch count, remat policy, the attention
block pair, optimizer-state gradient compression.  This is the "train"
member of the live co-tuning composite (``repro.serve.space.
make_live_cotune_sut``) — the subset of ``RunKnobs`` that acts on a
single-host measured train step, as opposed to the full dry-run knob space
(``repro.core.sut_jax.knob_space``) whose sharding/mesh knobs only mean
anything on the production mesh.

Like ``repro.serve.space``, this module stays numpy-only — building the
knob space must never pay the jax import.  ``apply_train_knobs`` (which
produces a ``RunKnobs``) imports lazily.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.core.params import Config, EnumParam, ParameterSpace

__all__ = ["train_knob_space", "apply_train_knobs"]


def train_knob_space(max_microbatches: int = 8) -> ParameterSpace:
    """The measured train step's tunable knobs (``RunKnobs`` fields).

    ``max_microbatches`` is the workload's global batch: microbatch counts
    must divide it, so only dividing powers of two up to it are offered.
    ``attn_block_* = 0`` keeps the model-config default.
    """
    mb_choices = tuple(m for m in (1, 2, 4, 8, 16)
                       if m <= max_microbatches and max_microbatches % m == 0)
    return ParameterSpace([
        # gradient-accumulation split of the global batch
        EnumParam("microbatches", mb_choices, 1),
        # activation rematerialization policy
        EnumParam("remat", ("none", "full", "dots"), "none"),
        # flash-attention tiling pair (0 = ModelConfig default)
        EnumParam("attn_block_q", (0, 128, 256, 512), 0),
        EnumParam("attn_block_kv", (0, 256, 512, 1024), 0),
        # optimizer gradient compression (error-feedback variants)
        EnumParam("compression", ("none", "int8", "topk"), "none"),
    ])


def apply_train_knobs(config: Config, base: Optional[Any] = None):
    """Tuned train knobs -> a ``RunKnobs`` (lazy import: the tuning path
    itself never needs jax).  ``base`` supplies the non-tuned fields; it
    defaults to data-parallel single-host knobs, the measured-SUT setting.
    """
    import dataclasses

    from repro.train.step import RunKnobs

    base = base or RunKnobs(rules_preset="dp")
    return dataclasses.replace(
        base,
        microbatches=int(config["microbatches"]),
        remat=str(config["remat"]),
        attn_block_q=int(config["attn_block_q"]),
        attn_block_kv=int(config["attn_block_kv"]),
        compression=str(config["compression"]),
    )
