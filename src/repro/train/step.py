"""Train/serve step factories — where the execution knobs live.

``RunKnobs`` is the configuration surface of the distributed runtime: remat
policy, microbatch count, loss chunking, MoE group size, gradient
compression, sharding-rule preset.  These are exactly the knobs
``repro.core.sut_jax`` exposes to the ACTS tuner — the paper's "configuration
setting" for this system.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.optim import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_grads,
    compression_init,
)

__all__ = ["RunKnobs", "make_train_step", "make_serve_step", "init_train_state"]


@dataclass(frozen=True)
class RunKnobs:
    rules_preset: str = "fsdp_tp"  # dp | tp | fsdp_tp (sharding-rule preset)
    remat: str = "full"  # none | full | dots
    microbatches: int = 4
    loss_chunk: int = 512  # 0 = unchunked cross-entropy
    moe_group: int = 4096
    compression: str = "none"  # none | int8 | topk
    donate: bool = True
    seq_shard: bool = False  # sequence parallelism for long prefill
    sp_residual: bool = False  # Megatron-SP: shard residual stream on seq
    kv_seq_shard: bool = False  # shard decode KV cache along sequence
    expert_tp: bool = False  # TP inside experts (expert_ff -> model)
    pad_heads: bool = False  # pad query heads to a shardable multiple (16)
    head_dim_shard: bool = False  # shard attention on head_dim, not heads
    attn_impl: Optional[str] = None  # override ModelConfig.attn_impl
    attn_block_q: int = 0  # 0 = keep ModelConfig default
    attn_block_kv: int = 0
    scan_unroll: int = 1
    # consult the kernel-autotune cache (repro.autotune) for attention
    # block sizes when no explicit attn_block_* override is given
    kernel_autotune: bool = False

    def resolved_attn_blocks(self, cfg, seq_len: int) -> Tuple[int, int]:
        """(block_q, block_kv) for this cell: explicit knob > autotune
        cache (when ``kernel_autotune``) > ModelConfig default."""
        bq, bkv = self.attn_block_q, self.attn_block_kv
        if self.kernel_autotune and (not bq or not bkv):
            from repro.autotune import cached_blocks

            tuned = cached_blocks(
                "flash_attention",
                {"B": 1, "S": seq_len, "SK": seq_len,
                 "H": cfg.padded_heads, "KV": cfg.n_kv_heads,
                 "D": cfg.head_dim_},
                cfg.compute_dtype)
            if tuned:
                bq = bq or int(tuned.get("block_q", 0))
                bkv = bkv or int(tuned.get("block_kv", 0))
        return bq or cfg.attn_block_q, bkv or cfg.attn_block_kv

    def axis_rules(self):
        from repro.dist.sharding import RULE_PRESETS

        rules = RULE_PRESETS[self.rules_preset]
        if self.seq_shard:
            rules = rules.replace(seq="model")
        if self.sp_residual:
            rules = rules.replace(seq_res="model")
        if self.kv_seq_shard:
            rules = rules.replace(kv_seq="model")
        if self.expert_tp:
            rules = rules.replace(expert_ff="model")
        if self.head_dim_shard:
            rules = rules.replace(heads=None, kv_heads=None,
                                  head_dim="model")
        return rules


def init_train_state(model: Model, rng, knobs: RunKnobs):
    params = model.init(rng)
    opt_state = adamw_init(params)
    if knobs.compression != "none":
        opt_state["error"] = compression_init(params, knobs.compression)
    return params, opt_state


def make_train_step(
    model: Model, opt_cfg: OptimizerConfig, knobs: RunKnobs
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Microbatching runs as a scan with f32 gradient accumulation
    (compute of microbatch i overlaps the reduction of i-1 under XLA's
    latency-hiding scheduler on real hardware)."""

    def loss_fn(params, mb):
        total, metrics = model.loss(
            params, mb, remat=knobs.remat, loss_chunk=knobs.loss_chunk,
            moe_group=knobs.moe_group)
        return total, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        k = knobs.microbatches
        if k <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                return x.reshape(k, b // k, *x.shape[1:])

            mbs = jax.tree_util.tree_map(reshape, batch)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc_g, acc_l, acc_m = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, gi: a + gi.astype(jnp.float32), acc_g, g)
                acc_m = jax.tree_util.tree_map(lambda a, x: a + x, acc_m, m)
                return (acc_g, acc_l + l, acc_m), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"loss": 0.0, "aux_loss": 0.0, "accuracy": 0.0,
                      "tokens": 0.0}
            zero_m = jax.tree_util.tree_map(jnp.float32, zero_m)
            (grads, loss, metrics), _ = jax.lax.scan(
                body, (zero_g, jnp.float32(0.0), zero_m), mbs,
                unroll=knobs.scan_unroll)
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = loss / k
            metrics = jax.tree_util.tree_map(lambda x: x / k, metrics)

        new_opt = dict(opt_state)
        if knobs.compression != "none":
            grads, new_err = compress_grads(grads, opt_state["error"],
                                            knobs.compression)
            new_opt["error"] = new_err
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        core_state = {k2: new_opt[k2] for k2 in ("mu", "nu", "step")}
        new_params, core_state, lr = adamw_update(grads, core_state, params,
                                                  opt_cfg)
        new_opt.update(core_state)
        metrics = dict(metrics, grad_norm=gnorm, learning_rate=lr,
                       total_loss=loss)
        return new_params, new_opt, metrics

    return train_step


def make_serve_step(model: Model) -> Callable:
    """serve_step(params, cache, tokens) -> (logits, new_cache): one decode
    step of one new token per sequence against the KV cache."""

    def serve_step(params, cache, tokens):
        return model.decode_step(params, tokens, cache)

    return serve_step
