from . import hlo
from .flops import model_flops

__all__ = ["hlo", "model_flops"]
