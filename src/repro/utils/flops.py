"""Analytic MODEL_FLOPS: 6·N·D for dense training, 6·N_active·D for MoE,
2·N·D for inference (decode/prefill) — the "useful compute" yardstick the
roofline report compares against compiled HLO FLOPs."""
from __future__ import annotations

from repro.configs import ModelConfig, ShapeSpec
from repro.models import count_params

__all__ = ["model_flops", "active_params"]


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top-k of E experts)."""
    n = count_params(cfg)
    if cfg.moe:
        from repro.models.moe import moe_defs
        from repro.models.common import count_def_params

        moe_per_block = count_def_params(moe_defs(cfg))
        n_moe_blocks = sum(1 for k in cfg.superblock if k.startswith("moe")) \
            * cfg.n_superblocks
        total_moe = moe_per_block * n_moe_blocks
        frac = cfg.moe.experts_per_token / cfg.moe.n_experts
        n = n - total_moe + int(total_moe * frac)
    return n


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Total useful FLOPs for one step of the given shape (whole cluster)."""
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch
