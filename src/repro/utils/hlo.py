"""Post-SPMD HLO analysis: collective bytes, op census, remat detection.

``compiled.as_text()`` is the optimized per-device module after the SPMD
partitioner has inserted collectives, so operand shapes are *shard* shapes.
We build an id -> bytes map from every instruction definition, then sum
operand bytes for each collective op — per-device collective traffic, which
``roofline.py`` converts into the collective roofline term.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["CollectiveStats", "parse_collectives", "dtype_bytes",
           "parse_shape_bytes", "count_ops"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# %name = bf16[2,16,128]{2,1,0} op-name(%a, %b), ...
_DEF_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}: ]+?))\s+"
    r"([\w\-]+)(?:\.\d+)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def dtype_bytes(dtype: str) -> int:
    return _DTYPE_BYTES.get(dtype, 4)


def parse_shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    ops: Dict[str, int] = field(default_factory=dict)  # kind -> count
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    total_bytes: int = 0  # per-device operand bytes across all collectives

    def summary(self) -> str:
        if not self.ops:
            return "no collectives"
        parts = [f"{k}×{self.ops[k]} ({self.bytes_by_kind[k] / 1e6:.1f}MB)"
                 for k in sorted(self.ops)]
        return ", ".join(parts) + f"; total {self.total_bytes / 1e6:.1f}MB"


def parse_collectives(hlo_text: str) -> CollectiveStats:
    # Pass 1: instruction id -> result bytes.
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, _op = m.groups()
        sizes[name] = parse_shape_bytes(type_str)

    # Pass 2: collective lines; sum operand bytes.
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        kind = next((c for c in COLLECTIVE_OPS if op.startswith(c)), None)
        if kind is None:
            continue
        if op.startswith(f"{kind}-start"):
            kind = kind  # async start carries the payload
        elif op.endswith("-done"):
            continue  # avoid double counting async pairs
        # operands: everything inside the first (...) group
        try:
            args = line.split("(", 1)[1]
            args = args.split(")", 1)[0]
        except IndexError:
            args = ""
        operand_bytes = 0
        for om in _OPERAND_RE.finditer(args):
            operand_bytes += sizes.get(om.group(1), 0)
        if operand_bytes == 0:
            operand_bytes = parse_shape_bytes(type_str)  # fallback: result
        stats.ops[kind] = stats.ops.get(kind, 0) + 1
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + \
            operand_bytes
        stats.total_bytes += operand_bytes
    return stats


def count_ops(hlo_text: str, ops: Tuple[str, ...] = ("fusion", "dot",
                                                     "convolution",
                                                     "custom-call")) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line.strip())
        if m:
            op = m.group(3)
            for o in ops:
                if op.startswith(o):
                    counts[o] += 1
    return dict(counts)
