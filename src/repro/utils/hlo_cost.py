"""Trip-count-aware static cost analysis of post-SPMD HLO.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so any program
built on ``lax.scan`` (layer stacks, microbatching, chunked attention/GLA)
underreports FLOPs, bytes and collective traffic by the trip count.  This
module re-derives the three roofline inputs from the HLO text itself:

* parse every computation and its instructions,
* build the call graph (while bodies/conditions, fusions, calls, branches),
* extract while trip counts from the canonical `compare(iv, constant)`
  condition pattern (what scan lowers to),
* propagate execution multipliers from ENTRY,
* FLOPs      = Σ dot/conv flops × multiplier            (MXU work),
* bytes      = Σ (operands + results) of top-level memory ops × multiplier
               (fusion-boundary traffic — XLA's own bytes-accessed notion),
* collectives = Σ operand bytes of collective ops × multiplier.

All quantities are per-device (the module is the SPMD per-device program).
This is a static estimate: elementwise flops inside fusions are ignored
(matmul-dominated workloads) and fusion-internal reuse is invisible — both
noted in EXPERIMENTS.md.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo"]

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_HEADER_PARAM = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\],{}]+))")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_INSTR = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}\/ ]+?))\s+"
    r"([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_ATTR_COMP = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONSTANT = re.compile(r"constant\((\d+)\)")
_DIMS_ATTR = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_ATTR = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that do not move data at fusion-boundary granularity
_NO_MEM = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
           "after-all", "token", "while", "conditional", "call", "iota",
           "partition-id", "replica-id"}


@dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class _Comp:
    name: str
    instrs: List[_Instr] = field(default_factory=list)
    is_entry: bool = False
    param_types: Dict[str, str] = field(default_factory=dict)


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0  # every fusion boundary (upper bound)
    mem_bytes: float = 0.0  # memory-op traffic: dot/slice/gather/collective
    collective_bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    n_while: int = 0
    trip_counts: Dict[str, int] = field(default_factory=dict)
    unresolved_trips: List[str] = field(default_factory=list)


# ops whose operands/results genuinely stream HBM on TPU (elementwise chains
# fuse into their producers/consumers and are excluded)
_MEM_OPS = ("dot", "convolution", "dynamic-slice", "dynamic-update-slice",
            "gather", "scatter", "copy") + tuple(COLLECTIVES)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",")] if dims else []


def _parse_computations(hlo: str) -> List[_Comp]:
    comps: List[_Comp] = []
    cur: Optional[_Comp] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and ("->" in line):
            m = _COMP_HEADER.match(line)
            if m:
                cur = _Comp(m.group(1), is_entry=line.startswith("ENTRY"))
                # record parameter names -> types (dot operands may be params)
                header_args = line.split("(", 1)[1].rsplit("->", 1)[0]
                for pm in _HEADER_PARAM.finditer(header_args):
                    cur.param_types[pm.group(1)] = pm.group(2)
                comps.append(cur)
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if m:
            name, type_str, op = m.groups()
            cur.instrs.append(_Instr(name, type_str, op, line))
    return comps


def _dot_flops(instr: _Instr, sizes: Dict[str, str]) -> float:
    """2 × |output| × contracted-dim-product for a dot instruction."""
    out = _shape_dims(instr.type_str)
    if out is None:
        return 0.0
    _, out_dims = out
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    # contracted size from the lhs operand's shape + contracting dims attr
    mdims = _DIMS_ATTR.search(instr.line)
    ops = _OPERAND.findall(instr.line.split("(", 1)[1])
    contracted = 1
    if mdims and ops:
        lhs_type = sizes.get(ops[0])
        if lhs_type:
            parsed = _shape_dims(lhs_type)
            if parsed:
                _, lhs_dims = parsed
                idxs = [int(i) for i in mdims.group(1).split(",") if i]
                for i in idxs:
                    if i < len(lhs_dims):
                        contracted *= lhs_dims[i]
    return 2.0 * out_elems * contracted


def analyze_hlo(hlo: str) -> HloCost:
    comps = _parse_computations(hlo)
    by_name = {c.name: c for c in comps}
    # instruction result types (global namespace is fine: names are unique)
    sizes: Dict[str, str] = {}
    for c in comps:
        sizes.update(c.param_types)
        for ins in c.instrs:
            sizes[ins.name] = ins.type_str

    cost = HloCost()

    # ---- trip counts for while conditions --------------------------------
    def trip_of(cond_name: str) -> Optional[int]:
        cond = by_name.get(cond_name)
        if cond is None:
            return None
        consts: Dict[str, int] = {}
        for ins in cond.instrs:
            m = _CONSTANT.search(ins.line)
            if m and ins.op == "constant":
                consts[ins.name] = int(m.group(1))
        for ins in cond.instrs:
            if ins.op == "compare" and ("direction=LT" in ins.line
                                        or "direction=GT" in ins.line):
                ops = _OPERAND.findall(ins.line.split("(", 1)[1])
                for o in ops:
                    if o in consts:
                        return consts[o]
        return None

    # ---- call edges -------------------------------------------------------
    # caller -> [(callee, kind)], kind in {body, cond, fusion/call/branch}
    edges: Dict[str, List[Tuple[str, float]]] = {c.name: [] for c in comps}
    for c in comps:
        for ins in c.instrs:
            if ins.op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                # preferred: XLA's own annotation on the while op
                mt = _TRIP_CFG.search(ins.line)
                trip = int(mt.group(1)) if mt else None
                if trip is None and cond:
                    trip = trip_of(cond)  # fallback: parse the condition
                cost.n_while += 1
                if trip is None:
                    trip = 1
                    cost.unresolved_trips.append(ins.name)
                else:
                    cost.trip_counts[ins.name] = trip
                if body:
                    edges[c.name].append((body, float(trip)))
                if cond:
                    edges[c.name].append((cond, float(trip + 1)))
            else:
                m2 = _BRANCHES.search(ins.line)
                if m2:
                    for b in _OPERAND.findall(m2.group(1)):
                        edges[c.name].append((b, 1.0))
                for m in _ATTR_COMP.finditer(ins.line):
                    key = m.group(0).split("=")[0]
                    if key in ("calls", "to_apply"):
                        edges[c.name].append((m.group(1), 1.0))

    # ---- propagate multipliers from ENTRY ---------------------------------
    mult: Dict[str, float] = {c.name: 0.0 for c in comps}
    for c in comps:
        if c.is_entry:
            mult[c.name] = 1.0
    # call graph is a DAG; a few passes reach the fixpoint
    for _ in range(64):
        changed = False
        new = {c.name: (1.0 if c.is_entry else 0.0) for c in comps}
        for caller, outs in edges.items():
            for callee, factor in outs:
                if callee in new:
                    new[callee] += mult.get(caller, 0.0) * factor
        for k in mult:
            if abs(new[k] - mult[k]) > 1e-9 * max(1.0, abs(mult[k])):
                changed = True
        mult = new
        if not changed:
            break

    # ---- accumulate costs --------------------------------------------------
    for c in comps:
        m = mult.get(c.name, 0.0)
        if m <= 0:
            continue
        for ins in c.instrs:
            if ins.op in ("dot", "convolution"):
                cost.flops += m * _dot_flops(ins, sizes)
            kind = next((k for k in COLLECTIVES if ins.op.startswith(k)), None)
            if kind and not ins.op.endswith("-done"):
                try:
                    args = ins.line.split("(", 1)[1].split(")", 1)[0]
                except IndexError:
                    args = ""
                ob = sum(_type_bytes(sizes.get(o, ""))
                         for o in _OPERAND.findall(args))
                if ob == 0:
                    ob = _type_bytes(ins.type_str)
                d = cost.collectives.setdefault(kind,
                                                {"count": 0.0, "bytes": 0.0})
                d["count"] += m
                d["bytes"] += m * ob
                cost.collective_bytes += m * ob
            if ins.op in _NO_MEM or ins.op.endswith("-done"):
                continue
            try:
                args = ins.line.split("(", 1)[1].split(")", 1)[0]
                operand_bytes = sum(_type_bytes(sizes.get(o, ""))
                                    for o in _OPERAND.findall(args))
            except IndexError:
                operand_bytes = 0
            result_bytes = _type_bytes(ins.type_str)
            cost.bytes_accessed += m * (result_bytes + operand_bytes)
            # HBM traffic model per op class:
            if ins.op.startswith("dynamic-slice"):
                # reads only the slice (== result)
                cost.mem_bytes += m * 2 * result_bytes
            elif ins.op.startswith("dynamic-update-slice"):
                # reads + writes the updated region (operand 1); the full
                # buffer aliases in place
                ops_list = _OPERAND.findall(args)
                upd = _type_bytes(sizes.get(ops_list[1], "")) if len(
                    ops_list) > 1 else result_bytes
                cost.mem_bytes += m * 2 * upd
            elif ins.op.startswith(("gather", "scatter")):
                cost.mem_bytes += m * 2 * result_bytes
            elif ins.op.startswith(("dot", "convolution", "copy")) or any(
                    ins.op.startswith(c) for c in COLLECTIVES):
                cost.mem_bytes += m * (result_bytes + operand_bytes)
            elif ins.op == "fusion":
                # a fusion containing real compute streams its boundary; pure
                # elementwise fusions do too, at the producer/consumer — but
                # counting every one double-counts chains, so only fusions
                # with a dot/gather/slice inside (kLoop wrappers) are charged
                callee = _ATTR_COMP.search(ins.line)
                inner = by_name.get(callee.group(1)) if callee else None
                if inner and any(i2.op in ("dot", "convolution", "gather",
                                           "scatter", "dynamic-slice",
                                           "dynamic-update-slice")
                                 for i2 in inner.instrs):
                    # charge result + slice-corrected operands
                    cost.mem_bytes += m * (result_bytes + min(
                        operand_bytes, 4 * result_bytes))
    return cost
