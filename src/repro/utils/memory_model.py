"""First-principles HBM-traffic model for the memory roofline term.

Static HLO byte-scraping cannot see what the TPU backend actually does —
elementwise fusion, loop-carry aliasing and VMEM residency decisions happen
below HLO — so boundary-byte counts overestimate HBM traffic by 10-100×.
The memory term is therefore modeled analytically from quantities the
framework knows exactly:

* **weight streaming** — per-tensor *consumed* bytes (sharded by the model
  axis only: FSDP shards are re-gathered per use, so they stream at TP-shard
  size) × passes (fwd + bwd + remat recompute) × microbatches,
* **activation traffic** — per-layer boundary tensors × tokens/device ×
  save/restore factor implied by the remat policy,
* **optimizer update** — stored param shard + both f32 moments, read+write,
* **embeddings/logits** — token gathers + the vocab-sharded logits block,
* **decode** — one full weight stream + KV-cache read (+1-token write).

The HLO-derived boundary bytes remain in the dry-run record as a diagnostic
upper bound.  All numbers are per-device bytes per step.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from repro.configs import ModelConfig, ShapeSpec
from repro.dist.sharding import AxisRules, spec_for_shape

__all__ = ["analytic_memory_bytes"]

_ACT_FACTORS = {  # boundary tensors written+read per layer, by remat policy
    "none": 12.0,  # every intermediate saved for bwd
    "dots": 5.0,  # matmul outputs saved, elementwise recomputed
    "full": 1.5,  # superblock boundaries only; recompute stays on-chip
}


def _consumed_weight_bytes(defs, rules: AxisRules, mesh_shape: Dict[str, int],
                           fsdp_regather: bool = True) -> float:
    """Per-device bytes of weights as *consumed* by matmuls (TP-sharded;
    FSDP axes re-gathered) and as *stored* (sharded by everything)."""
    import jax
    from repro.models.common import ParamDef

    is_def = lambda x: isinstance(x, ParamDef)
    consumed = stored = 0.0
    for leaf in jax.tree_util.tree_leaves(defs, is_leaf=is_def):
        n = float(np.prod(leaf.shape))
        bytes_ = n * np.dtype(
            leaf.dtype if not hasattr(leaf.dtype, "dtype") else leaf.dtype
        ).itemsize if not str(leaf.dtype).startswith("bfloat") else n * 2
        div_model = div_all = 1.0
        for dim, ax in zip(leaf.shape, leaf.axes):
            target = rules.lookup(ax) if ax else None
            if target is None:
                continue
            axes = (target,) if isinstance(target, str) else tuple(target)
            size = 1
            for a in axes:
                size *= mesh_shape.get(a, 1)
            if size <= 1 or dim % size:
                continue
            div_all *= size
            if "model" in axes:
                div_model *= mesh_shape.get("model", 1)
        consumed += bytes_ / div_model
        stored += bytes_ / div_all
    return consumed, stored


def analytic_memory_bytes(
    cfg: ModelConfig,
    shape: ShapeSpec,
    *,
    rules: AxisRules,
    mesh_shape: Dict[str, int],
    remat: str = "full",
    microbatches: int = 1,
) -> Dict[str, float]:
    from repro.models.transformer import model_defs

    defs = model_defs(cfg)
    consumed_w, stored_w = _consumed_weight_bytes(defs, rules, mesh_shape)

    chips = 1
    for v in mesh_shape.values():
        chips *= v
    # batch extent follows the actual rules (dp_all maps batch over the
    # model axis too); divisibility fallback mirrors spec_for_shape
    target = rules.lookup("batch")
    axes = ((target,) if isinstance(target, str) else tuple(target or ()))
    batch_axes = 1
    for a in axes:
        batch_axes *= mesh_shape.get(a, 1)
    if batch_axes == 0 or shape.global_batch % batch_axes:
        batch_axes = 1
    b_dev = max(shape.global_batch // batch_axes, 1)
    model_ax = mesh_shape.get("model", 1)
    act_dt = 2.0  # bf16 activations

    out: Dict[str, float] = {}
    if shape.kind == "train":
        tokens_dev = b_dev * shape.seq_len
        passes = 2.0 + (1.0 if remat == "full" else 0.5 if remat == "dots"
                        else 0.0)
        out["weights"] = consumed_w * passes * microbatches
        out["activations"] = (cfg.n_layers * tokens_dev * cfg.d_model *
                              act_dt * _ACT_FACTORS[remat])
        # grads written once (stored sharding) + optimizer read/write
        out["optimizer"] = stored_w * 2 + stored_w / 2 * (4 + 4) * 2 * 2
        vshard = cfg.padded_vocab // (model_ax if cfg.padded_vocab %
                                      model_ax == 0 else 1)
        out["logits"] = tokens_dev * vshard * act_dt * 3
        out["embeddings"] = tokens_dev * cfg.d_model * act_dt * 4
    elif shape.kind == "prefill":
        tokens_dev = b_dev * shape.seq_len
        out["weights"] = consumed_w
        out["activations"] = (cfg.n_layers * tokens_dev * cfg.d_model *
                              act_dt * 2)
        out["kv_cache_write"] = _cache_bytes(cfg, b_dev, shape.seq_len,
                                             model_ax)
        out["logits"] = b_dev * cfg.padded_vocab // max(model_ax, 1) * act_dt
        out["embeddings"] = tokens_dev * cfg.d_model * act_dt * 2
    else:  # decode: one token per sequence
        out["weights"] = consumed_w
        out["kv_cache_read"] = _cache_bytes(cfg, b_dev, shape.seq_len,
                                            model_ax)
        out["activations"] = cfg.n_layers * b_dev * cfg.d_model * act_dt * 4
        out["logits"] = b_dev * cfg.padded_vocab // max(model_ax, 1) * act_dt
    out["total"] = sum(out.values())
    return out


def _cache_bytes(cfg: ModelConfig, b_dev: int, seq_len: int,
                 model_ax: int) -> float:
    """Per-device KV/state cache bytes (full read), honoring SWA windows,
    recurrent O(1) states and kv-head sharding fallback."""
    total = 0.0
    kv_shard = model_ax if cfg.n_kv_heads % model_ax == 0 else 1
    for kind in cfg.superblock:
        if kind in ("attn", "moe", "dec", "shared"):
            s = seq_len
        elif kind in ("swa", "moe_swa"):
            s = min(cfg.window or seq_len, seq_len)
        elif kind == "mamba2":
            d_inner = cfg.ssm_expand * cfg.d_model
            nh = cfg.ssm_heads or max(1, d_inner // 64)
            total += cfg.n_superblocks * b_dev * (
                nh * cfg.ssm_state * (d_inner // nh) * 4 +
                (cfg.ssm_conv - 1) * (d_inner + 2 * cfg.ssm_state) * 4)
            continue
        elif kind == "mlstm":
            d_in = cfg.ssm_expand * cfg.d_model
            dh = d_in // cfg.n_heads
            total += cfg.n_superblocks * b_dev * (
                cfg.n_heads * dh * (dh + 1) * 4 + (cfg.ssm_conv - 1) * d_in * 4)
            continue
        elif kind == "slstm":
            total += cfg.n_superblocks * b_dev * 4 * cfg.d_model * 4
            continue
        elif kind == "cross":
            continue
        else:
            continue
        total += (cfg.n_superblocks * 2 * b_dev * s *
                  (cfg.n_kv_heads // kv_shard) * cfg.head_dim_ * 2)
    return total
