"""Minimal deterministic stand-in for `hypothesis` (used when the real
package is not installed — this container cannot pip install).

Implements exactly the surface the test-suite uses: ``given``/``settings``
and the ``integers``/``floats``/``sampled_from``/``lists`` strategies.
Examples are drawn from a fixed-seed RNG so runs are reproducible; there is
no shrinking — a failing example is reported as-is by pytest.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: np.random.Generator):
        return self._draw(rng)


def integers(min_value=None, max_value=None):
    lo = -(2**31) if min_value is None else int(min_value)
    hi = 2**31 - 1 if max_value is None else int(max_value)
    return Strategy(lambda rng: int(rng.integers(lo, hi + 1)))


def floats(min_value=None, max_value=None, exclude_max=False, **_kw):
    lo = 0.0 if min_value is None else float(min_value)
    hi = 1.0 if max_value is None else float(max_value)

    def draw(rng):
        v = lo + rng.random() * (hi - lo)
        if exclude_max and v >= hi:
            v = np.nextafter(hi, lo)
        return float(v)

    return Strategy(draw)


def sampled_from(elements):
    elements = list(elements)
    return Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def lists(elements: Strategy, min_size=0, max_size=10, **_kw):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]

    return Strategy(draw)


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        max_examples = getattr(fn, "_stub_max_examples", None)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = max_examples or getattr(wrapper, "_stub_max_examples",
                                        DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(abs(hash(fn.__qualname__)) % 2**32)
            for _ in range(n):
                drawn_args = tuple(s.example(rng) for s in arg_strategies)
                drawn_kw = {k: s.example(rng) for k, s in
                            kw_strategies.items()}
                fn(*args, *drawn_args, **kwargs, **drawn_kw)

        # pytest must not see the strategy-filled parameters as fixtures:
        # hide the original signature and expose only what remains (self).
        wrapper.__dict__.pop("__wrapped__", None)
        params = list(inspect.signature(fn).parameters.values())
        kept, skipped_positional = [], 0
        for p in params:
            if p.name in kw_strategies:
                continue
            if p.name != "self" and skipped_positional < len(arg_strategies):
                skipped_positional += 1
                continue
            kept.append(p)
        wrapper.__signature__ = inspect.Signature(kept)
        return wrapper

    return deco


def install() -> None:
    """Register this module as `hypothesis` + `hypothesis.strategies`."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "lists"):
        setattr(strat, name, globals()[name])
    hyp.strategies = strat
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
