import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:  # pragma: no cover - prefer the real package when present
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    _hypothesis_stub.install()
