"""Lint fixture: planted allocator-discipline violation.  Never
imported — the lint parses it as text.  Expected findings:

* alloc-try-no-release  (the first try acquires but its handler only
                         logs; the second function's unwind path calls
                         release_all and must NOT be flagged)
"""


def leaky(alloc, rid, n):
    try:
        pages = alloc.reserve(rid, n)
        return pages
    except RuntimeError:
        return None


def disciplined(alloc, rid, n):
    try:
        pages = alloc.reserve(rid, n)
        more = alloc.extend(rid, n)
        return pages, more
    except BaseException:
        alloc.release_all()
        raise


def untried(values, alloc_log):
    # extend on a non-allocator receiver inside a try: not a finding
    try:
        values.extend([1, 2, 3])
    except TypeError:
        pass
    return values
