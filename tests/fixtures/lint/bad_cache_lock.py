"""Planted cache lock-discipline hazards (parsed, never executed).

``LeakyCache`` mirrors ``repro.autotune.cache.AutotuneCache``'s shape
but drops the flock dominance: its write path is reachable through
``put()`` without the sidecar lock — the cross-process race PR 10's
interprocedural dominance check exists to catch.
"""
import contextlib
import json
import os


class LeakyCache:
    def __init__(self, path):
        self.path = path
        self._data = {}

    @contextlib.contextmanager
    def _file_lock(self):
        yield  # the real one flocks a sidecar; shape is what matters

    def _write(self):
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as fh:          # BAD: reachable unlocked
            json.dump(self._data, fh)
        os.replace(tmp, self.path)          # BAD: reachable unlocked

    def put(self, key, value):
        self._data[key] = value             # BAD: mutation, no lock
        self._write()

    def put_locked(self, key, value):
        with self._file_lock():
            self._data[key] = value         # OK: under the flock
            tmp = f"{self.path}.tmp2"
            with open(tmp, "w") as fh:      # OK: under the flock
                json.dump(self._data, fh)
            os.replace(tmp, self.path)      # OK: under the flock

    def get(self, key):
        return self._data.get(key)          # OK: read path

    def _load(self, raw):
        self._data = dict(raw)              # OK: rebind, not mutation


class DisciplinedCache:
    """Every write path is lock-dominated — zero findings expected."""

    def __init__(self, path):
        self.path = path
        self._data = {}

    @contextlib.contextmanager
    def _file_lock(self):
        yield

    def _save(self, delta):
        with self._file_lock():
            self._data.update(delta)
            tmp = f"{self.path}.tmp"
            with open(tmp, "w") as fh:
                json.dump(self._data, fh)
            os.replace(tmp, self.path)

    def put(self, key, value):
        self._save({key: value})
