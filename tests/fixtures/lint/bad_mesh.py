"""Lint fixture: mesh/sharding hazards (see test_lint.py).

One jitted function closes over a module-level NamedSharding (BAD), one
takes the mesh as an explicit argument (OK), one closes over it without
being jitted (OK — plain python re-reads the global every call).  One
``constrain`` call passes a logical axis no rules preset maps (BAD) next
to a fully-known call (OK) and a non-literal one the lint must skip.
"""
import functools

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.dist.sharding import constrain
from repro.launch.mesh import make_mesh

MESH = make_mesh(1, 2)
SHARDING = NamedSharding(MESH, PartitionSpec("data"))


@jax.jit
def closes_over_mesh(x):  # BAD: jit cache never keys on the closure
    return jax.device_put(x, SHARDING)


@functools.partial(jax.jit, static_argnames=("n",))
def explicit_sharding_arg(x, sharding, n=2):  # OK: explicit argument
    del n
    return jax.device_put(x, sharding)


def not_jitted(x):  # OK: no jit cache to go stale
    return jax.device_put(x, SHARDING)


def typo_axis(x, dynamic_axis):
    x = constrain(x, "batch", None, "heds")  # BAD: unknown logical axis
    x = constrain(x, "batch", "seq", "head_dim")  # OK: all known
    return constrain(x, dynamic_axis, None, None)  # skipped: not literal
