"""Lint fixture: planted pallas_call contract violations.  Never
imported — the lint parses it as text.  Expected findings:

* pallas-index-map-arity  (second in_spec lambda takes 2 args, grid has 3)
* pallas-operand-arity    (immediate call passes 3 operands for 2 specs)
* pallas-kernel-arity     (kernel exposes 5 refs; 2 in + 1 out + 1
                           scratch = 4 expected)
* pallas-vmem-scratch     (warning: constant 32 MiB scratch over budget)
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, s_ref, o_ref, scratch_ref, extra_ref, *, eps):
    o_ref[...] = x_ref[...] * s_ref[...] + eps


def bad_call(x, scale):
    n, d = 8, 128
    return pl.pallas_call(
        functools.partial(_kernel, eps=1e-6),
        grid=(n, 2, 2),
        in_specs=[
            pl.BlockSpec((8, d), lambda i, j, k: (i, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((8, d), lambda i, j, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[
            pltpu.VMEM((2048, 4096), jnp.float32),
        ],
    )(x, scale, scale)
