"""Lint fixture: planted jit retrace hazards.  Never imported — the lint
parses it as text.  Expected findings:

* jit-static-missing       (line ~14: 'block_size' is not a param)
* jit-static-mutable-default (line ~22: static 'shape' defaults to a list)
* jit-traced-str-default   (line ~30: traced 'mode' defaults to a str)
"""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("causal", "block_size"))
def attention(q, k, v, *, causal=True):
    return q + k + v if causal else q


@functools.partial(jax.jit, static_argnames=("shape",))
def windowed(x, *, shape=[128, 128]):
    return x.reshape(shape)


@jax.jit
def normalize(x, mode="rms"):
    return x if mode == "rms" else -x
