"""Planted determinism-taint hazards (never executed, only parsed).

Each BAD block leaks a nondeterministic value into a decision sink;
each OK block is the matching accepted pattern and must stay clean —
this file doubles as the precision spec for the taint engine.
"""
import time
import os

import numpy as np


class PerfMetric:  # stand-in for repro.core metric records
    def __init__(self, value=0.0, wall_s=0.0):
        self.value = value
        self.wall_s = wall_s


# --- BAD: wall clock perturbs a victim decision ------------------------
def tainted_victim(scheduler, running):
    jitter = time.time()
    ranked = [(r, jitter) for r in running]
    return scheduler.select_victim(ranked)


# --- BAD: wall clock seeds a sampling key ------------------------------
def tainted_key(jax_random):
    seed = int(time.time() * 1e6)
    return jax_random.PRNGKey(seed)


# --- BAD: interprocedural — timer -> helper -> helper -> candidate gen -
def _jitter():
    return time.perf_counter()


def _derive(x):
    return int(x * 1e3)


def bad_candidates(space):
    rng = np.random.default_rng(_derive(_jitter()))
    return lhs(space, 8, rng)


def lhs(space, m, rng):
    return [space for _ in range(m)]


# --- BAD: wall clock controls a retune trigger (decision branch) -------
def tainted_retune(retuner, window, t0, steps):
    if time.perf_counter() - t0 > 30.0:
        return retuner.maybe_retune(window, steps)
    return None


# --- BAD: set iteration order reaches a cache-key signature ------------
def set_order_sig(pages):
    live = {p for p in pages}
    first = list(live)
    return mesh_sig(first[0])


def mesh_sig(mesh):
    return str(mesh)


# --- BAD: os entropy into the global-rng sink --------------------------
def entropy_seed():
    return np.random.default_rng(int.from_bytes(os.urandom(4), "little"))


# --- OK: timers accumulating into a metric record (engine.py pattern) --
def timed_metrics(run_once):
    t0 = time.time()
    run_once()
    best = time.perf_counter() - t0
    return PerfMetric(value=best, wall_s=time.time() - t0)


# --- OK: seeded generator feeding candidate generation -----------------
def seeded_candidates(space):
    rng = np.random.default_rng(0)
    return lhs(space, 8, rng)


# --- OK: sorted() launders set iteration order -------------------------
def sorted_sig(pages):
    live = {p for p in pages}
    return mesh_sig(sorted(live)[0])


# --- OK: a step-counted retune trigger (PR 8's fix shape) --------------
def step_counted_retune(retuner, window, steps):
    if steps % 512 == 0:
        return retuner.maybe_retune(window, steps)
    return None
