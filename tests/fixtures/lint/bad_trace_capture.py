"""Planted jit trace-capture / host-effect hazards (parsed, not run).

Includes the PR 9 regression shape: a bound method of a shared model
jitted in a module that builds meshes (the pre-``_jit_mesh_keyed``
pattern) — bound methods of one object hash equal, so two engines over
different meshes silently share one jaxpr cache entry.
"""
import functools

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_mesh

_SCRATCH = {"scale": 1.0}   # mutable module state (mutated below)
STEP_LOG = []               # mutable module state (mutated under trace)
VMEM_LIMIT = 16 * 2 ** 20   # immutable module constant: fine to close over


def set_scale(v):
    _SCRATCH["scale"] = float(v)


# --- BAD: jitted function reads live mutable module state --------------
@jax.jit
def captures_mutable(x):
    return x * _SCRATCH["scale"]


# --- BAD: host effects under trace (print + closure mutation) ----------
@jax.jit
def logs_under_trace(x):
    print("tracing", x.shape)
    STEP_LOG.append(int(x.shape[0]))
    return x + 1


# --- OK: immutable constant capture + jax.debug.print ------------------
@jax.jit
def reads_constant(x):
    jax.debug.print("shape {s}", s=x.shape)
    return x * (VMEM_LIMIT // VMEM_LIMIT)


class SharedModel:
    def decode_step(self, tokens):
        return tokens + 1


class LeakyEngine:
    """The PR 9 bug shape: pre-``_jit_mesh_keyed`` engines."""

    def __init__(self, model, data, tp):
        self.mesh = make_mesh(data, tp)        # ambient mesh context
        self.model = model
        # BAD: bound method of the *shared* model — jaxprs traced under
        # this engine's mesh are reused by every other engine
        self._decode = jax.jit(model.decode_step)

    def _greedy(self, logits):
        return jnp.argmax(logits, axis=-1)

    def attach(self):
        # OK: bound method of self — per-instance, the accepted pattern
        self._argmax = jax.jit(self._greedy)


class FixedEngine:
    """The PR 9 fix shape: a fresh per-engine closure keys the cache."""

    def __init__(self, model, data, tp):
        self.mesh = make_mesh(data, tp)
        self._decode = self._jit_keyed(model.decode_step)

    def _jit_keyed(self, fn):
        @functools.wraps(fn)
        def keyed(*args, **kwargs):   # identity-hashed per engine: OK
            return fn(*args, **kwargs)

        return jax.jit(keyed)
