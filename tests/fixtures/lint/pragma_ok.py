"""Lint fixture: every planted hazard is pragma-suppressed — the lint
must report zero findings for this file."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("missing",))  # lint: ignore[jit-static-missing]
def suppressed_named(x):
    return x


@jax.jit
def suppressed_all(x, mode="rms"):  # lint: ignore
    return x


def suppressed_alloc(alloc, rid, n):
    try:
        return alloc.reserve(rid, n)  # lint: ignore[alloc-try-no-release]
    except RuntimeError:
        return None


MESH = make_mesh(1, 2)  # noqa: F821 - fixture, never imported


@jax.jit
def suppressed_mesh_closure(x):
    return jax.device_put(x, MESH)  # lint: ignore[jit-mesh-closure]


def suppressed_axis(x):
    return constrain(x, "heds")  # noqa: F821  # lint: ignore[constrain-unknown-axis]


# --- PR 10 rule families, each suppressed on its finding line ----------
import time  # noqa: E402

_LIVE_STATE = {"scale": 1.0}


def bump_scale():
    _LIVE_STATE["scale"] = 2.0


@jax.jit
def suppressed_capture(x):
    print("traced", x)  # lint: ignore[jit-host-effect]
    return x * _LIVE_STATE["scale"]  # lint: ignore[jit-trace-capture]


def suppressed_taint(scheduler, rows):
    jitter = time.time()
    return scheduler.select_victim([(r, jitter) for r in rows])  # lint: ignore[determinism-taint]


class SuppressedCache:
    def __init__(self, path):
        self.path = path
        self._data = {}

    def _file_lock(self):
        raise NotImplementedError

    def put(self, key, value):
        self._data[key] = value  # lint: ignore[cache-lock-discipline]
