"""Lint fixture: every planted hazard is pragma-suppressed — the lint
must report zero findings for this file."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("missing",))  # lint: ignore[jit-static-missing]
def suppressed_named(x):
    return x


@jax.jit
def suppressed_all(x, mode="rms"):  # lint: ignore
    return x


def suppressed_alloc(alloc, rid, n):
    try:
        return alloc.reserve(rid, n)  # lint: ignore[alloc-try-no-release]
    except RuntimeError:
        return None
