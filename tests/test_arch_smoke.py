"""Per-architecture smoke tests (deliverable f).

Every assigned architecture is instantiated at a REDUCED config of the same
family (same superblock pattern / block kinds, tiny widths) and runs one
forward + one gradient (train) step on CPU, asserting output shapes and the
absence of NaNs.  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, list_configs, reduced, shape_applicable
from repro.models import Model, count_params

BATCH, SEQ = 2, 32


def make_batch(cfg, batch=BATCH, seq=SEQ, rng=None):
    rng = rng or np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab_size, size=(batch, seq)).astype(np.int32)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    if cfg.frontend or cfg.encoder:
        out["frontend_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_tokens, cfg.frontend_dim)),
            jnp.float32)
    return out


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(get_config(name))
            m = Model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, m, params)
        return cache[name]

    return get


def test_all_archs_registered():
    assert list_configs() == ARCH_IDS


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_and_loss(name, models):
    cfg, m, params = models(name)
    batch = make_batch(cfg)
    hidden, aux = m.forward(params, batch)
    assert hidden.shape == (BATCH, SEQ, cfg.d_model)
    assert not bool(jnp.isnan(hidden).any())
    loss, metrics = m.loss(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    # untrained model should sit near uniform over the true vocab
    assert 0.5 * np.log(cfg.vocab_size) < float(metrics["loss"]) < 2.5 * np.log(
        cfg.vocab_size)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_train_grad_step(name, models):
    cfg, m, params = models(name)
    batch = make_batch(cfg)

    def loss_fn(p):
        return m.loss(p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert not bool(jnp.isnan(loss))
    flat, _ = jax.tree_util.tree_flatten(grads)
    assert all(not bool(jnp.isnan(g).any()) for g in flat)
    # at least the embedding gradient must be non-zero
    assert float(jnp.abs(grads["embed"]).sum()) > 0


@pytest.mark.parametrize("name", ARCH_IDS)
def test_loss_chunking_matches(name, models):
    """Chunked cross-entropy must equal the unchunked computation."""
    cfg, m, params = models(name)
    batch = make_batch(cfg)
    l_full, _ = m.loss(params, batch, loss_chunk=0)
    l_chunk, _ = m.loss(params, batch, loss_chunk=8)
    np.testing.assert_allclose(float(l_full), float(l_chunk), rtol=2e-5)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_remat_matches(name, models):
    cfg, m, params = models(name)
    batch = make_batch(cfg)
    l0, _ = m.loss(params, batch, remat="none")
    l1, _ = m.loss(params, batch, remat="full")
    np.testing.assert_allclose(float(l0), float(l1), rtol=2e-5)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_prefill_decode_consistency(name, models):
    """KV-cache path must reproduce full-forward logits: prefill S tokens,
    then decode token S and compare against forward over S+1 tokens."""
    cfg, m, params = models(name)
    rng = np.random.default_rng(1)
    S = 24
    batch_full = make_batch(cfg, seq=S + 1, rng=rng)
    tokens = batch_full["tokens"]

    # ground truth: full forward, logits at position S-1 predict token S
    hidden, _ = m.forward(params, dict(batch_full, tokens=tokens))
    logits_full = m._logits(params, hidden)

    cache = m.init_cache(BATCH, max_seq=S + 8)
    prefill_batch = dict(batch_full, tokens=tokens[:, :S])
    logits_pre, cache = m.prefill(params, prefill_batch, cache)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0]), np.asarray(logits_full[:, S - 1]),
        rtol=5e-3, atol=5e-3)

    logits_dec, cache = m.decode_step(params, tokens[:, S:S + 1], cache)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, S]),
        rtol=5e-3, atol=5e-3)
    assert int(cache["index"]) == S + 1


@pytest.mark.parametrize("name", ARCH_IDS)
def test_shape_applicability_rules(name):
    cfg = get_config(name)
    ok_long, reason = shape_applicable(cfg, SHAPES["long_500k"])
    if name in ("xlstm-350m", "zamba2-1.2b", "mixtral-8x22b"):
        assert ok_long, f"{name} should run long_500k"
    else:
        assert not ok_long and reason
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        assert shape_applicable(cfg, SHAPES[s])[0]


class TestPublishedParamCounts:
    """Full configs must land near the published sizes."""

    EXPECTED_B = {
        "xlstm-350m": (0.30, 0.45),
        "gemma-7b": (7.8, 9.3),
        "qwen2.5-32b": (30.0, 34.5),
        "starcoder2-15b": (14.0, 17.0),
        "gemma3-12b": (10.8, 13.2),
        "llama-3.2-vision-90b": (80.0, 95.0),
        "seamless-m4t-medium": (0.45, 1.4),
        "mixtral-8x22b": (135.0, 147.0),
        "grok-1-314b": (300.0, 330.0),
        "zamba2-1.2b": (0.95, 1.45),
    }

    @pytest.mark.parametrize("name", ARCH_IDS)
    def test_count(self, name):
        lo, hi = self.EXPECTED_B[name]
        n = count_params(get_config(name)) / 1e9
        assert lo <= n <= hi, f"{name}: {n:.2f}B not in [{lo}, {hi}]"


@pytest.mark.parametrize("name", ["xlstm-350m", "zamba2-1.2b"])
def test_pallas_gla_impl_matches_jnp(name, models):
    """Models running on the Pallas GLA kernel (interpret mode) must match
    the pure-jnp core exactly."""
    import dataclasses

    cfg, m, params = models(name)
    cfg_k = dataclasses.replace(cfg, gla_impl="pallas")
    m_k = Model(cfg_k)
    batch = make_batch(cfg, batch=1, seq=24)
    h0, _ = m.forward(params, batch)
    h1, _ = m_k.forward(params, batch)
    # per-layer agreement is ~4e-6; tolerance covers f32 reassociation
    # accumulating through up to 36 recurrent blocks
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                               rtol=2e-3, atol=5e-3)
