"""Kernel autotune subsystem: spaces, cost model, cache, kernel threading."""
import json
import logging
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import autotune
from repro.autotune import (
    AutotuneCache,
    KERNELS,
    KernelSUT,
    KernelSpace,
    shape_sig,
)

FA_DIMS = {"B": 1, "S": 256, "SK": 256, "H": 4, "KV": 2, "D": 32}


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune.reset_default_cache()
    yield path
    autotune.reset_default_cache()


class TestKernelSpace:
    @pytest.mark.parametrize("kernel", sorted(KERNELS))
    def test_spaces_are_valid(self, kernel):
        ks = KernelSpace(kernel)
        space = ks.space()
        assert space.dim >= 1
        cfg = space.default_config()
        space.validate(cfg)
        assert set(cfg) == set(ks.knobs)

    def test_missing_dims_rejected(self):
        with pytest.raises(ValueError, match="missing dims"):
            KernelSpace("flash_attention").validate_dims({"B": 1})

    def test_unknown_kernel_rejected(self):
        with pytest.raises(ValueError, match="unknown kernel"):
            KernelSpace("conv3d")

    def test_sig_is_canonical(self):
        assert shape_sig({"S": 256, "B": 1}) == shape_sig({"B": 1, "S": 256})


class TestCostModel:
    @pytest.mark.parametrize("kernel,dims", [
        ("flash_attention", FA_DIMS),
        ("flash_attention", dict(FA_DIMS, SK=1024)),  # cache-prefill shape
        ("flash_attention", dict(FA_DIMS, SK=64)),    # cross-attn, SK < S
        ("decode_attention", FA_DIMS),
        ("gla", {"B": 1, "S": 256, "H": 2, "DK": 32, "DV": 32}),
        ("rmsnorm", {"ROWS": 1024, "D": 512}),
    ])
    def test_model_finite_and_positive(self, kernel, dims):
        d = KERNELS[kernel]
        space = d.make_space()
        for cfg in [space.default_config(),
                    space.from_unit_vector(np.full(space.dim, 0.01)),
                    space.from_unit_vector(np.full(space.dim, 0.97))]:
            cost = d.model_cost(cfg, dims, "float32")
            assert cost > 0

    def test_vmem_overflow_is_infeasible(self):
        d = KERNELS["flash_attention"]
        big = {"B": 1, "S": 1 << 20, "SK": 1 << 20, "H": 1, "KV": 1,
               "D": 4096}
        cost = d.model_cost({"block_q": 512, "block_kv": 512}, big,
                            "float32")
        assert cost == float("inf")


class TestCacheRoundTrip:
    def test_tune_persist_reload_same_blocks(self, tmp_cache):
        """The acceptance criterion: tune → persist → reload → same blocks
        under interpret mode on CPU."""
        res = autotune.autotune_kernel("flash_attention", FA_DIMS,
                                       budget=12, interpret=True, seed=0)
        assert res["mode"] == "model"  # interpret => deterministic model
        assert os.path.exists(tmp_cache)
        # a brand-new cache object re-reads the file from disk
        fresh = AutotuneCache(tmp_cache)
        got = autotune.cached_blocks("flash_attention", FA_DIMS, "float32",
                                     cache=fresh)
        assert got == res["config"]
        # and the default-cache path (what ops.py uses) agrees
        autotune.reset_default_cache()
        assert autotune.cached_blocks("flash_attention", FA_DIMS,
                                      "float32") == res["config"]

    def test_ensure_tuned_is_idempotent(self, tmp_cache):
        first = autotune.ensure_tuned("rmsnorm", {"ROWS": 512, "D": 128},
                                      budget=8, interpret=True)
        blob = json.load(open(tmp_cache))
        second = autotune.ensure_tuned("rmsnorm", {"ROWS": 512, "D": 128},
                                      budget=8, interpret=True)
        assert first == second
        assert json.load(open(tmp_cache)) == blob  # no re-tune, no rewrite

    def test_entries_keyed_by_shape_and_dtype(self, tmp_cache):
        autotune.autotune_kernel("rmsnorm", {"ROWS": 512, "D": 128},
                                 budget=6, interpret=True)
        autotune.autotune_kernel("rmsnorm", {"ROWS": 2048, "D": 128},
                                 budget=6, interpret=True)
        cache = AutotuneCache(tmp_cache)
        assert len(cache) == 2
        assert autotune.cached_blocks("rmsnorm", {"ROWS": 512, "D": 128},
                                      "bfloat16", cache=cache) is None


class TestKernelSUTTiming:
    def test_time_mode_measures(self):
        sut = KernelSUT("rmsnorm", {"ROWS": 64, "D": 32}, mode="time",
                        interpret=True, timing_iters=1)
        m = sut.test({"block_rows": 16})
        assert m.value > 0 and not m.higher_is_better
        assert m.metrics["mode"] == "time"


class TestKernelThreading:
    """Block overrides flow from the cache through the public entry points."""

    def test_ops_consult_cache_and_stay_correct(self, tmp_cache):
        from repro.kernels import ops
        from repro.kernels.ref import attention_ref, rmsnorm_ref

        # seed the cache with a deliberately non-default (but valid) tiling
        cache = autotune.default_cache()
        cache.put("rmsnorm", shape_sig({"ROWS": 8, "D": 32}), "float32",
                  autotune.backend_name(), {"block_rows": 8}, 1.0)
        dims = {"B": 1, "S": 64, "SK": 64, "H": 2, "KV": 2, "D": 16}
        cache.put("flash_attention", shape_sig(dims), "float32",
                  autotune.backend_name(),
                  {"block_q": 16, "block_kv": 32}, 1.0)

        # dim_semantics rides along (builtin default when never tuned)
        resolved = ops._resolve("rmsnorm", {"ROWS": 8, "D": 32},
                                "float32", {"block_rows": None})
        assert resolved == {"block_rows": 8, "dim_semantics": "parallel"}
        resolved = ops._resolve("flash_attention", dims, "float32",
                                {"block_q": None, "block_kv": None})
        assert resolved == {"block_q": 16, "block_kv": 32,
                            "dim_semantics": "parallel"}
        # explicit overrides always win over the cache
        resolved = ops._resolve("flash_attention", dims, "float32",
                                {"block_q": 64, "block_kv": None})
        assert resolved == {"block_q": 64, "block_kv": 32,
                            "dim_semantics": "parallel"}

        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
        out = ops.flash_attention(q, k, v)  # tuned blocks picked up
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(attention_ref(q, k, v)),
            rtol=2e-5, atol=2e-5)
        x = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
        s = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(ops.rmsnorm(x, s)),
            np.asarray(rmsnorm_ref(x, s)), rtol=2e-5, atol=2e-5)

    def test_pallas_entry_points_accept_overrides(self):
        from repro.kernels.decode_attention import flash_decode_pallas
        from repro.kernels.gla import gla_pallas
        from repro.kernels.ref import attention_ref, gla_ref

        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.normal(size=(2, 4, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 48, 2, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 48, 2, 8)), jnp.float32)
        for bkv in (8, 16, 48):
            out = flash_decode_pallas(q, k, v, 48, block_kv=bkv,
                                      interpret=True)
            ref = attention_ref(q[:, None], k, v, causal=False)[:, 0]
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=3e-5, atol=3e-5)
        gq = jnp.asarray(rng.normal(size=(1, 32, 1, 8)), jnp.float32)
        gg = jnp.asarray(-np.abs(rng.normal(size=(1, 32, 1)) * 0.3),
                         jnp.float32)
        for chunk in (8, 16):
            y, _ = gla_pallas(gq, gq, gq, gg, chunk=chunk, interpret=True)
            yr, _ = gla_ref(gq, gq, gq, gg)
            np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                       rtol=5e-5, atol=5e-5)


class TestKVSeqLenInSignature:
    """Regression: the flash_attention cache key must include the KV
    sequence length (SK).  Before the fix the key used only the KV *head
    count*, so cross-attention / cache-prefill problems with different KV
    lengths collided on one entry."""

    def test_distinct_keys_for_differing_kv_lengths(self, tmp_cache):
        cache = autotune.default_cache()
        cache.put("flash_attention", shape_sig(FA_DIMS), "float32",
                  autotune.backend_name(), {"block_q": 32, "block_kv": 32},
                  1.0)
        same = autotune.cached_blocks("flash_attention", FA_DIMS, "float32")
        assert same == {"block_q": 32, "block_kv": 32}
        # same query length, longer KV stream: a DIFFERENT problem
        longer = dict(FA_DIMS, SK=512)
        assert autotune.cached_blocks("flash_attention", longer,
                                      "float32") is None
        assert shape_sig(FA_DIMS) != shape_sig(longer)

    def test_ops_resolve_keys_on_kv_length(self, tmp_cache):
        from repro.kernels import ops

        cache = autotune.default_cache()
        self_attn = {"B": 1, "S": 64, "SK": 64, "H": 2, "KV": 2, "D": 16}
        cache.put("flash_attention", shape_sig(self_attn), "float32",
                  autotune.backend_name(), {"block_q": 16, "block_kv": 16},
                  1.0)
        hit = ops._resolve("flash_attention", self_attn, "float32",
                           {"block_q": None, "block_kv": None})
        assert hit == {"block_q": 16, "block_kv": 16,
                       "dim_semantics": "parallel"}
        # cache-prefill shape (same S, longer SK) must NOT inherit it;
        # it falls back to the builtin defaults
        prefill = dict(self_attn, SK=128)
        miss = ops._resolve("flash_attention", prefill, "float32",
                            {"block_q": None, "block_kv": None})
        assert miss == ops.DEFAULT_BLOCKS["flash_attention"]

    def test_sk_required_in_dims(self):
        with pytest.raises(ValueError, match="missing dims"):
            KernelSpace("flash_attention").validate_dims(
                {"B": 1, "S": 256, "H": 4, "KV": 2, "D": 32})

    def test_cost_model_distinguishes_kv_length(self):
        d = KERNELS["flash_attention"]
        cfg = {"block_q": 64, "block_kv": 64}
        short = d.model_cost(cfg, FA_DIMS, "float32")
        long_ = d.model_cost(cfg, dict(FA_DIMS, SK=4096), "float32")
        assert long_ > short  # more KV to stream must cost more


class TestCacheSchemaVersion:
    """Regression: the SK fix invalidates pre-SK entries via a key schema
    bump — old keys can never resolve and are dropped on rewrite."""

    def test_keys_are_versioned(self):
        key = AutotuneCache.key("flash_attention", "sig", "float32", "cpu")
        assert key.startswith(f"v{autotune.SCHEMA_VERSION}|")

    def test_old_schema_entries_invalidated(self, tmp_cache):
        stale = {
            # v1 (unversioned) key: flash_attention signature without SK
            "flash_attention|B1_D32_H4_KV2_S256|float32|cpu": {
                "config": {"block_q": 999, "block_kv": 999},
                "value": 1.0, "meta": {}, "time": 0.0},
        }
        with open(tmp_cache, "w") as f:
            json.dump(stale, f)
        cache = AutotuneCache(tmp_cache)
        assert autotune.cached_blocks("flash_attention", FA_DIMS,
                                      "float32", cache=cache) is None
        # a write rewrites the file without the stale entry
        cache.put("rmsnorm", shape_sig({"ROWS": 8, "D": 32}), "float32",
                  "cpu", {"block_rows": 8}, 1.0)
        on_disk = json.load(open(tmp_cache))
        assert all(k.startswith(f"v{autotune.SCHEMA_VERSION}|")
                   for k in on_disk)

    def test_newer_schema_entries_survive(self, tmp_cache):
        """A shared cache file touched by a NEWER binary must not lose that
        binary's entries when this version writes — only older schemas are
        invalidated."""
        future = f"v{autotune.SCHEMA_VERSION + 1}|rmsnorm|D32_ROWS8" \
                 "|float32|tpu"
        with open(tmp_cache, "w") as f:
            json.dump({future: {"config": {"block_rows": 8}, "value": 1.0,
                                "meta": {}, "time": 0.0}}, f)
        cache = AutotuneCache(tmp_cache)
        cache.put("rmsnorm", shape_sig({"ROWS": 8, "D": 32}), "float32",
                  "cpu", {"block_rows": 16}, 1.0)
        on_disk = json.load(open(tmp_cache))
        assert future in on_disk  # preserved, not erased
        assert len(on_disk) == 2


class TestResolveBlocksErrorHandling:
    """Regression: resolve_blocks used a bare ``except Exception`` that
    silently masked cache corruption — now it warns once, names the cache
    path, and only catches the expected failure set."""

    def _corrupt_cache(self, path):
        key = AutotuneCache.key("rmsnorm", shape_sig({"ROWS": 8, "D": 32}),
                                "float32", autotune.backend_name())
        with open(path, "w") as f:
            json.dump({key: ["structurally", "corrupt"]}, f)
        return AutotuneCache(path)

    def test_corrupted_cache_warns_once_and_falls_back(self, tmp_cache,
                                                       caplog):
        from repro.autotune import api

        api._warned_cache_paths.clear()
        cache = self._corrupt_cache(tmp_cache)
        defaults = {"block_rows": 256}
        with caplog.at_level(logging.WARNING, logger="repro.autotune"):
            out = autotune.resolve_blocks("rmsnorm", {"ROWS": 8, "D": 32},
                                          "float32", defaults, cache=cache)
        assert out == defaults
        warnings = [r for r in caplog.records
                    if r.levelno == logging.WARNING]
        assert len(warnings) == 1
        assert tmp_cache in warnings[0].getMessage()  # names the path
        # one-time: a second failing lookup does not warn again
        caplog.clear()
        with caplog.at_level(logging.WARNING, logger="repro.autotune"):
            out2 = autotune.resolve_blocks("rmsnorm", {"ROWS": 8, "D": 32},
                                           "float32", defaults, cache=cache)
        assert out2 == defaults
        assert not [r for r in caplog.records
                    if r.levelno == logging.WARNING]

    def test_unexpected_errors_propagate(self, tmp_cache, monkeypatch):
        from repro.autotune import api

        def boom(*a, **kw):
            raise RuntimeError("programming error")

        monkeypatch.setattr(api, "cached_blocks", boom)
        with pytest.raises(RuntimeError, match="programming error"):
            api.resolve_blocks("rmsnorm", {"ROWS": 8, "D": 32}, "float32",
                               {"block_rows": 256})

    def test_caller_errors_propagate(self):
        """Bad call-site arguments (unknown kernel, missing signature
        dims — e.g. a site not migrated to SK) must raise, not silently
        resolve to defaults."""
        with pytest.raises(ValueError, match="missing dims"):
            autotune.resolve_blocks(
                "flash_attention",
                {"B": 1, "S": 256, "H": 4, "KV": 2, "D": 32},  # no SK
                "float32", {"block_q": 128, "block_kv": 128})
        with pytest.raises(ValueError, match="unknown kernel"):
            autotune.resolve_blocks("conv3d", {"B": 1}, "float32", {})


class TestConcurrentWriters:
    """Regression: two processes tuning into ONE cache file must not lose
    entries.  Before the merge-on-save fix, each writer dumped its stale
    in-memory view wholesale — writer B's put erased writer A's entry."""

    @staticmethod
    def _sig(rows):
        return shape_sig({"ROWS": rows, "D": 32})

    def test_interleaved_writers_keep_all_entries(self, tmp_cache):
        a = AutotuneCache(tmp_cache)
        b = AutotuneCache(tmp_cache)
        # both load their (empty) in-memory views before either writes —
        # the lost-update schedule
        assert a.get("rmsnorm", self._sig(8), "float32", "cpu") is None
        assert b.get("rmsnorm", self._sig(16), "float32", "cpu") is None
        a.put("rmsnorm", self._sig(8), "float32", "cpu",
              {"block_rows": 8}, 1.0)
        b.put("rmsnorm", self._sig(16), "float32", "cpu",
              {"block_rows": 16}, 2.0)  # b's view never saw a's entry
        on_disk = json.load(open(tmp_cache))
        assert len(on_disk) == 2
        fresh = AutotuneCache(tmp_cache)
        assert fresh.get_config("rmsnorm", self._sig(8), "float32",
                                "cpu") == {"block_rows": 8}
        assert fresh.get_config("rmsnorm", self._sig(16), "float32",
                                "cpu") == {"block_rows": 16}

    def test_many_interleavings_union(self, tmp_cache):
        """N writers alternating puts: the file ends with all N*K keys."""
        writers = [AutotuneCache(tmp_cache) for _ in range(3)]
        for w in writers:  # load stale (empty) views up front
            assert w.get("rmsnorm", self._sig(1), "float32", "cpu") is None
        for k in range(4):
            for i, w in enumerate(writers):
                w.put("rmsnorm", self._sig(100 * (k + 1) + i), "float32",
                      "cpu", {"block_rows": 8}, float(k))
        assert len(json.load(open(tmp_cache))) == 12

    def test_writers_see_merged_state_after_put(self, tmp_cache):
        """After its own put, a writer's in-memory view includes entries
        merged from disk — no reload needed to resolve them."""
        a = AutotuneCache(tmp_cache)
        b = AutotuneCache(tmp_cache)
        assert b.get("rmsnorm", self._sig(8), "float32", "cpu") is None
        a.put("rmsnorm", self._sig(8), "float32", "cpu",
              {"block_rows": 8}, 1.0)
        b.put("rmsnorm", self._sig(16), "float32", "cpu",
              {"block_rows": 16}, 2.0)
        # b merged a's entry during its save
        assert b.get_config("rmsnorm", self._sig(8), "float32",
                            "cpu") == {"block_rows": 8}

    def test_stale_view_does_not_revert_other_writers_values(self,
                                                             tmp_cache):
        """Value-level lost update: writer A's stale in-memory copy of a
        key another process re-tuned must NOT ride along when A writes an
        unrelated key — only the keys a writer actually modified overlay
        the file."""
        a = AutotuneCache(tmp_cache)
        a.put("rmsnorm", self._sig(8), "float32", "cpu",
              {"block_rows": 8}, 1.0)  # a's view now holds the old entry
        b = AutotuneCache(tmp_cache)
        b.put("rmsnorm", self._sig(8), "float32", "cpu",
              {"block_rows": 32}, 2.0)  # b re-tunes the SAME key
        a.put("rmsnorm", self._sig(16), "float32", "cpu",
              {"block_rows": 16}, 1.0)  # a writes something unrelated
        fresh = AutotuneCache(tmp_cache)
        assert fresh.get_config("rmsnorm", self._sig(8), "float32",
                                "cpu") == {"block_rows": 32}  # b's survives

    def test_merge_still_drops_stale_schemas(self, tmp_cache):
        """Merge-on-save must not resurrect older-schema entries."""
        stale_key = "flash_attention|B1|float32|cpu"  # v1 (unversioned)
        with open(tmp_cache, "w") as f:
            json.dump({stale_key: {"config": {}, "value": 1.0,
                                   "meta": {}, "time": 0.0}}, f)
        cache = AutotuneCache(tmp_cache)
        cache.put("rmsnorm", self._sig(8), "float32", "cpu",
                  {"block_rows": 8}, 1.0)
        on_disk = json.load(open(tmp_cache))
        assert stale_key not in on_disk
        assert len(on_disk) == 1


class TestTrainConfigCache:
    """The live joint mode's train-step entry: persists + reloads alongside
    kernel and serve-config entries in the same cache file."""

    def test_put_and_reload(self, tmp_cache):
        sig_dims = {"S": 32, "B": 8, "H": 4, "KV": 4, "D": 16}
        knobs = {"microbatches": 2, "remat": "none", "attn_block_q": 0,
                 "attn_block_kv": 0, "compression": "none"}
        autotune.put_train_config(sig_dims, "float32", knobs, 1234.5)
        assert autotune.cached_train_config(sig_dims, "float32") == knobs
        # keyed by workload shape: a different microbatch seq misses
        assert autotune.cached_train_config(dict(sig_dims, S=64),
                                            "float32") is None
        fresh = AutotuneCache(os.environ["REPRO_AUTOTUNE_CACHE"])
        assert autotune.cached_train_config(sig_dims, "float32",
                                            cache=fresh) == knobs

    def test_three_system_entries_coexist(self, tmp_cache):
        """Kernel + serve_engine + train_step winners in ONE file — what
        --joint --real persists."""
        autotune.default_cache().put(
            "decode_attention", shape_sig({"B": 8, "S": 128, "H": 4,
                                           "KV": 4, "D": 16}),
            "float32", "cpu", {"block_kv": 128}, 100.0)
        autotune.put_serve_config({"S": 128, "H": 4, "KV": 4, "D": 16},
                                  "float32", {"max_batch": 8}, 100.0)
        autotune.put_train_config({"S": 32, "B": 8, "H": 4, "KV": 4,
                                   "D": 16}, "float32",
                                  {"microbatches": 2}, 100.0)
        on_disk = json.load(open(os.environ["REPRO_AUTOTUNE_CACHE"]))
        systems = {k.split("|")[1] for k in on_disk}
        assert systems == {"decode_attention", autotune.SERVE_SYSTEM,
                           autotune.TRAIN_SYSTEM}


class TestServeConfigCache:
    """The joint mode's serve-config entry: persists + reloads alongside
    kernel entries in the same cache file."""

    def test_put_and_reload(self, tmp_cache):
        sig_dims = {"S": 2048, "H": 16, "KV": 4, "D": 64}
        knobs = {"max_batch": 32, "prefill_chunk": 512,
                 "kv_cache_pages": 4096, "schedule": "fifo"}
        autotune.put_serve_config(sig_dims, "float32", knobs, 3900.0)
        assert autotune.cached_serve_config(sig_dims, "float32") == knobs
        # keyed by shape: a different serving window misses
        other = dict(sig_dims, S=4096)
        assert autotune.cached_serve_config(other, "float32") is None
        # fresh cache object re-reads from disk
        fresh = AutotuneCache(tmp_cache)
        assert autotune.cached_serve_config(sig_dims, "float32",
                                            cache=fresh) == knobs

    def test_pre_sharing_cache_entry_still_deploys(self, tmp_cache):
        """Regression: winners persisted before the share_prefix/draft_len
        knobs existed must deploy with both features off (the page_policy
        precedent) — widening the knob space must not invalidate caches
        written by older builds."""
        from repro.serve.space import apply_serve_knobs

        sig_dims = {"S": 256, "H": 4, "KV": 4, "D": 16}
        old_shape = {"max_batch": 8, "prefill_chunk": 128,
                     "kv_cache_pages": 512, "schedule": "sjf"}
        autotune.put_serve_config(sig_dims, "float32", old_shape, 1234.0)
        loaded = autotune.cached_serve_config(sig_dims, "float32")
        assert "share_prefix" not in loaded and "draft_len" not in loaded
        cfg = apply_serve_knobs(loaded)
        assert cfg.schedule == "sjf"
        assert cfg.page_policy == "reserve"  # the PR-5 back-compat rule
        assert cfg.share_prefix is False and cfg.draft_len == 0
        # and a widened-space winner round-trips the new knobs
        new_shape = dict(old_shape, share_prefix=1, draft_len=4,
                         page_policy="on_demand")
        autotune.put_serve_config(sig_dims, "float32", new_shape, 2000.0)
        cfg2 = apply_serve_knobs(autotune.cached_serve_config(
            sig_dims, "float32"))
        assert cfg2.share_prefix is True and cfg2.draft_len == 4
        assert cfg2.page_policy == "on_demand"


class TestWorkloadKeyedEntries:
    """(PR 8) v3 keys carry a trailing workload-signature component, so
    serve winners tuned under different live request mixes coexist at one
    model shape — the online retuner's transfer set."""

    SIG_DIMS = {"S": 48, "H": 4, "KV": 2, "D": 16}
    WS = "a0.50_d12_g8_p24_r0.35_s0.30_x0.60"

    def test_workload_and_generic_entries_coexist(self, tmp_cache):
        autotune.put_serve_config(self.SIG_DIMS, "float32",
                                  {"max_batch": 4}, 100.0)
        autotune.put_serve_config(self.SIG_DIMS, "float32",
                                  {"max_batch": 8}, 200.0,
                                  workload=self.WS)
        generic = autotune.cached_serve_config(self.SIG_DIMS, "float32")
        at_ws = autotune.cached_serve_config(self.SIG_DIMS, "float32",
                                             workload=self.WS)
        assert generic == {"max_batch": 4}
        assert at_ws == {"max_batch": 8}
        # an unknown signature is an exact-key miss (transfer is the
        # caller's job, via serve_config_candidates)
        assert autotune.cached_serve_config(
            self.SIG_DIMS, "float32", workload="a9.99_d1_g1_p1_r0_s0_x0"
        ) is None

    def test_candidates_scan_by_signature(self, tmp_cache):
        autotune.put_serve_config(self.SIG_DIMS, "float32",
                                  {"max_batch": 4}, 100.0)
        autotune.put_serve_config(self.SIG_DIMS, "float32",
                                  {"max_batch": 8}, 200.0,
                                  workload=self.WS)
        # a different shape must not leak into the candidate set
        autotune.put_serve_config(dict(self.SIG_DIMS, S=96), "float32",
                                  {"max_batch": 2}, 50.0, workload=self.WS)
        cands = autotune.serve_config_candidates(self.SIG_DIMS, "float32")
        assert set(cands) == {"-", self.WS}
        assert cands[self.WS]["config"] == {"max_batch": 8}
        assert cands["-"]["config"] == {"max_batch": 4}

    def test_workload_component_is_sanitized(self, tmp_cache):
        """A ``|`` inside a workload string must not corrupt the key
        layout (it is the key separator)."""
        cache = autotune.default_cache()
        cache.put("k", "s", "float32", "cpu", {"a": 1}, 1.0,
                  workload="bad|sig")
        assert cache.get("k", "s", "float32", "cpu",
                         workload="bad|sig")["config"] == {"a": 1}
        on_disk = json.load(open(os.environ["REPRO_AUTOTUNE_CACHE"]))
        assert all(len(k.split("|")) == 7 for k in on_disk)
        assert set(cache.scan_workloads("k", "s", "float32", "cpu")) == \
            {"bad/sig"}


class TestCacheKeyCanonicalization:
    """(PR 8 satellite) Every producer must serialize the identical key
    from equivalent inputs: numpy integer dims, python ints, and the
    three entry kinds (kernel / serve / train) all round-trip through one
    canonical form — a formatting mismatch is a silent cache miss."""

    def test_numpy_dims_key_like_python_ints(self, tmp_cache):
        np_dims = {"S": np.int64(48), "H": np.int32(4),
                   "KV": np.int64(2), "D": np.int32(16)}
        py_dims = {"S": 48, "H": 4, "KV": 2, "D": 16}
        autotune.put_serve_config(np_dims, "float32", {"max_batch": 4},
                                  1.0)
        assert autotune.cached_serve_config(py_dims, "float32") == \
            {"max_batch": 4}
        autotune.put_train_config(dict(py_dims, B=np.int64(8)), "float32",
                                  {"microbatches": 2}, 1.0)
        assert autotune.cached_train_config(dict(py_dims, B=8),
                                            "float32") == \
            {"microbatches": 2}

    def test_all_three_entry_kinds_round_trip(self, tmp_cache):
        """One file, three producers, one schema: every entry written
        through its public producer reloads from a FRESH cache object
        (true disk round-trip) under the current schema version."""
        kernel_sig = shape_sig({"ROWS": 8, "D": 32})
        autotune.default_cache().put("rmsnorm", kernel_sig, "float32",
                                     "cpu", {"block_rows": 8}, 10.0)
        autotune.put_serve_config({"S": 48, "H": 4, "KV": 2, "D": 16},
                                  "float32", {"max_batch": 4}, 20.0,
                                  workload="a0.50_d1_g1_p1_r0.00_s0.00_x?")
        autotune.put_train_config({"S": 32, "B": 8, "H": 4, "KV": 4,
                                   "D": 16}, "float32",
                                  {"microbatches": 2}, 30.0)
        fresh = AutotuneCache(os.environ["REPRO_AUTOTUNE_CACHE"])
        assert fresh.get_config("rmsnorm", kernel_sig, "float32",
                                "cpu") == {"block_rows": 8}
        assert autotune.cached_serve_config(
            {"S": 48, "H": 4, "KV": 2, "D": 16}, "float32",
            workload="a0.50_d1_g1_p1_r0.00_s0.00_x?",
            cache=fresh) == {"max_batch": 4}
        assert autotune.cached_train_config(
            {"S": 32, "B": 8, "H": 4, "KV": 4, "D": 16}, "float32",
            cache=fresh) == {"microbatches": 2}
        on_disk = json.load(open(os.environ["REPRO_AUTOTUNE_CACHE"]))
        assert len(on_disk) == 3
        for k in on_disk:
            parts = k.split("|")
            assert parts[0] == f"v{autotune.SCHEMA_VERSION}"
            assert len(parts) == 7  # workload + mesh on EVERY key

    def test_key_is_pure_string_function(self):
        assert AutotuneCache.key("k", "s", "float32", "cpu") == \
            AutotuneCache.key("k", "s", "float32", "cpu", workload="")
        assert AutotuneCache.key("k", "s", "float32", "cpu").endswith("|-|1dev")


class TestSchemaV2Migration:
    """(PR 8) The v3 bump MIGRATES v2 entries (same meaning, generic
    workload signature) instead of dropping them — a pre-PR8 tuned cache
    keeps its winners."""

    V2_KEY = "v2|rmsnorm|D32_ROWS8|float32|cpu"

    def _seed_v2(self, path):
        with open(path, "w") as f:
            json.dump({self.V2_KEY: {
                "config": {"block_rows": 8}, "value": 42.0,
                "meta": {"mode": "est"}, "time": 0.0}}, f)

    def test_v2_entry_resolves_at_generic_workload(self, tmp_cache):
        self._seed_v2(tmp_cache)
        cache = AutotuneCache(tmp_cache)
        got = cache.get("rmsnorm", "D32_ROWS8", "float32", "cpu")
        assert got and got["config"] == {"block_rows": 8}
        assert got["value"] == 42.0

    def test_migration_becomes_physical_on_write(self, tmp_cache):
        self._seed_v2(tmp_cache)
        cache = AutotuneCache(tmp_cache)
        cache.put("other", "sig", "float32", "cpu", {"a": 1}, 1.0)
        on_disk = json.load(open(tmp_cache))
        assert self.V2_KEY not in on_disk
        migrated = f"v{autotune.SCHEMA_VERSION}|rmsnorm|D32_ROWS8" \
                   "|float32|cpu|-|1dev"
        assert on_disk[migrated]["config"] == {"block_rows": 8}

    def test_native_v3_wins_over_migrated_v2(self, tmp_cache):
        """A re-tuned (native current-schema) entry must never be
        shadowed by its pre-migration ancestor sharing the file."""
        native = AutotuneCache.key("rmsnorm", "D32_ROWS8", "float32",
                                   "cpu")
        with open(tmp_cache, "w") as f:
            json.dump({
                self.V2_KEY: {"config": {"block_rows": 8}, "value": 42.0,
                              "meta": {}, "time": 0.0},
                native: {"config": {"block_rows": 16}, "value": 99.0,
                         "meta": {}, "time": 1.0},
            }, f)
        cache = AutotuneCache(tmp_cache)
        assert cache.get_config("rmsnorm", "D32_ROWS8", "float32",
                                "cpu") == {"block_rows": 16}

    def test_pre_v2_still_drops(self, tmp_cache):
        with open(tmp_cache, "w") as f:
            json.dump({"rmsnorm|D32_ROWS8|float32|cpu": {
                "config": {"block_rows": 8}, "value": 1.0, "meta": {},
                "time": 0.0}}, f)
        cache = AutotuneCache(tmp_cache)
        assert cache.get("rmsnorm", "D32_ROWS8", "float32", "cpu") is None
