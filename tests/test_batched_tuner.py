"""Batched evaluation engine: parity, budget accounting, call counts.

The contract under test (core/tuner.py + core/base.py):

* batched and sequential engines run the IDENTICAL trial sequence — same
  seed + budget gives the same best config, same best value and the same
  test count on any SUT,
* the resource limit stays exact in both modes (cache hits free, distinct
  tests counted, rounds truncated at the limit),
* the batched engine collapses each optimizer round into one evaluator
  call: a budget-B run costs O(rounds), not O(B), SUT invocations.
"""
import math

import numpy as np
import pytest

from repro.core import (
    BoolParam,
    CallableSUT,
    FloatParam,
    MySQLSurrogate,
    ParameterSpace,
    PerfMetric,
    SparkSurrogate,
    TomcatSurrogate,
    Tuner,
)
from repro.core.rrs import RRSOptimizer


def _run(sut, budget, seed, batch):
    tuner = Tuner(sut.space(), sut, budget=budget, seed=seed, batch=batch)
    return tuner.run(), tuner


class TestBatchedSequentialParity:
    @pytest.mark.parametrize("surrogate_cls", [MySQLSurrogate,
                                               TomcatSurrogate,
                                               SparkSurrogate])
    @pytest.mark.parametrize("seed", [0, 1, 7])
    def test_identical_best_and_trial_count(self, surrogate_cls, seed):
        budget = 200
        rb, tb = _run(surrogate_cls(), budget, seed, batch=True)
        rs, ts = _run(surrogate_cls(), budget, seed, batch=False)
        assert tb.batch and not ts.batch
        assert rb.best_config == rs.best_config
        assert rb.best_metric.value == rs.best_metric.value
        assert rb.n_tests == rs.n_tests == budget
        # the full trial streams match, not just the argmin
        assert [t.config for t in rb.history] == \
               [t.config for t in rs.history]
        assert [t.value for t in rb.history] == \
               [t.value for t in rs.history]

    def test_parity_with_tiny_budgets(self):
        """Round truncation at the resource limit is mode-independent."""
        for budget in (1, 2, 3, 7, 45):
            rb, _ = _run(MySQLSurrogate(), budget, 3, batch=True)
            rs, _ = _run(MySQLSurrogate(), budget, 3, batch=False)
            assert rb.n_tests == rs.n_tests == budget
            assert rb.best_config == rs.best_config


class TestBudgetAccounting:
    def test_batched_budget_exact(self):
        calls = []

        class CountingMySQL(MySQLSurrogate):
            def test_batch(self, configs):
                calls.append(len(configs))
                return super().test_batch(configs)

        rep, _ = _run(CountingMySQL(), 500, 0, batch=True)
        assert rep.n_tests == sum(calls) == 500

    def test_duplicates_within_a_round_are_free(self):
        space = ParameterSpace([BoolParam("a"), BoolParam("b")])
        evaluated = []

        def batch_fn(configs):
            evaluated.extend(tuple(sorted(c.items())) for c in configs)
            return [PerfMetric(value=1.0 + c["a"] + 0.5 * c["b"])
                    for c in configs]

        def fn(config):
            return batch_fn([config])[0]

        sut = CallableSUT(fn, batch_fn=batch_fn)
        rep = Tuner(space, sut, budget=50, seed=0).run()
        assert len(set(evaluated)) == len(evaluated)  # never re-tested
        assert rep.n_tests <= 4


class TestEvaluatorCallRegression:
    def test_batched_path_issues_round_level_calls(self):
        """Budget-500 RRS must cost O(rounds) evaluator calls, not O(500).

        The smallest round is the exploitation round (n_exploit samples),
        so ceil(budget / n_exploit) + 1 (the default-config test) bounds
        the batched engine's SUT invocations from above; the sequential
        engine pays one invocation per test.
        """
        budget = 500
        rb, tb = _run(MySQLSurrogate(), budget, 0, batch=True)
        rs, ts = _run(MySQLSurrogate(), budget, 0, batch=False)
        n_exploit = RRSOptimizer().n_exploit
        assert tb.n_evaluator_calls <= math.ceil(budget / n_exploit) + 1
        # and in practice far fewer: most trials land in big LHS/explore
        # rounds, so the call count is an order of magnitude under budget
        assert tb.n_evaluator_calls < budget / 5
        assert ts.n_evaluator_calls == budget

    def test_sequential_fallback_for_test_only_suts(self):
        """A SUT without test_batch transparently uses per-config calls."""
        calls = []
        surrogate = MySQLSurrogate()

        def fn(config):
            calls.append(config)
            return surrogate.test(config)

        tuner = Tuner(surrogate.space(), CallableSUT(fn), budget=30, seed=0)
        assert not tuner.batch  # auto-detect: no test_batch attribute
        rep = tuner.run()
        assert rep.n_tests == len(calls) == 30


class TestBatchObjectivePrefix:
    def test_short_prefix_recorded_before_stop(self):
        """When the SUT budget cuts a round short, the evaluated prefix
        must still enter the history (what a loop would have left)."""
        space = ParameterSpace([FloatParam("x", 0.0, 1.0, default=0.5)])

        def fn(config):
            return PerfMetric(value=config["x"], higher_is_better=False)

        rep = Tuner(space, CallableSUT(fn), budget=10, seed=0).run()
        assert rep.n_tests == 10
        assert len(rep.history) >= 10
