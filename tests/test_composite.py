"""Cross-system co-tuning: CompositeSpace, CompositeSUT, subspace_rr.

Contracts under test (core/composite.py + serve/space.py):

* CompositeSpace prefixes member knobs, delegates conversion per subspace
  (frozen views keep their fixed values, custom Parameter subclasses keep
  their kernels) and its vectorized matrix path matches the scalar path.
* CompositeSUT is one SUT under one budget: batched rounds dispatch as a
  SINGLE test_batch call per member, and batched vs sequential runs of the
  same seed evaluate the identical trial sequence.
* SubspaceRoundRobinOptimizer (BestConfig divide-and-diverge) respects the
  budget, improves over the default, and keeps batched/sequential parity.
* The co-deployment surrogate rewards joint tuning: at equal total budget
  the joint optimum is at least as good as independently tuned members.
"""
import math

import numpy as np
import pytest

from repro.core import (
    CompositeSpace,
    CompositeSUT,
    FloatParam,
    FrontendSurrogate,
    IntParam,
    MySQLSurrogate,
    ParameterSpace,
    PerfMetric,
    SubspaceRoundRobinOptimizer,
    Tuner,
    get_optimizer,
    throughput_under_sla,
    weighted_objective,
)
from repro.core.tuner import BatchEvaluator  # noqa: F401 (protocol exists)
from repro.serve.space import (
    CotuneParams,
    ServeSurrogate,
    coupled_serve_metrics,
    make_cotune_sut,
    serve_knob_space,
)


class OddIntParam(IntParam):
    """Custom parameter: always lands on odd values (conversion-delegation
    probe — the composite must route its columns through this kernel)."""

    def from_unit(self, u: float) -> int:
        v = super().from_unit(u)
        return v if v % 2 == 1 else min(self.hi, v + 1)


def _toy_spaces():
    a = ParameterSpace([FloatParam("x", 0.0, 1.0, default=0.5),
                        IntParam("n", 1, 10, default=2)])
    b = ParameterSpace([OddIntParam("m", 1, 99, default=3)])
    return a, b


class TestCompositeSpace:
    def test_prefixing_and_structure(self):
        a, b = _toy_spaces()
        cs = CompositeSpace({"a": a, "b": b})
        assert cs.names == ["a.x", "a.n", "b.m"]
        assert cs.dim == 3
        assert cs.subspace_names == ["a", "b"]
        assert cs.column_groups() == {"a": [0, 1], "b": [2]}
        assert cs.subspace("b") is b

    def test_split_join_roundtrip(self):
        a, b = _toy_spaces()
        cs = CompositeSpace({"a": a, "b": b})
        cfg = cs.default_config()
        parts = cs.split(cfg)
        assert parts == {"a": {"x": 0.5, "n": 2}, "b": {"m": 3}}
        assert cs.join(parts) == cfg
        with pytest.raises(ValueError):
            cs.split({"nosuch.k": 1})
        with pytest.raises(ValueError):
            cs.split({"unprefixed": 1})

    def test_bad_member_names_rejected(self):
        a, _ = _toy_spaces()
        with pytest.raises(ValueError):
            CompositeSpace({"with.dot": a})
        with pytest.raises(ValueError):
            CompositeSpace({"": a})
        with pytest.raises(ValueError):
            CompositeSpace({})

    def test_matrix_matches_scalar_path_with_custom_param(self):
        """Per-subspace conversion: the batch path must route each member's
        columns through that member's own kernels (incl. subclasses)."""
        a, b = _toy_spaces()
        cs = CompositeSpace({"a": a, "b": b})
        u = np.random.default_rng(0).random((64, cs.dim))
        batch = cs.from_unit_matrix(u)
        assert batch == [cs.from_unit_vector(row) for row in u]
        assert all(cfg["b.m"] % 2 == 1 for cfg in batch)

    def test_frozen_member_keeps_fixed_values(self):
        a, b = _toy_spaces()
        frozen = a.freeze({"n": 7})
        cs = CompositeSpace({"a": frozen, "b": b})
        assert cs.dim == 2  # a.x + b.m; a.n pinned
        cfg = cs.default_config()
        assert cfg["a.n"] == 7
        for got in cs.from_unit_matrix(np.random.default_rng(1).random((5, 2))):
            assert got["a.n"] == 7
        cs.validate(cfg)

    def test_to_unit_vector_roundtrip(self):
        a, b = _toy_spaces()
        cs = CompositeSpace({"a": a, "b": b})
        cfg = cs.from_unit_vector(np.array([0.3, 0.6, 0.9]))
        again = cs.from_unit_vector(cs.to_unit_vector(cfg))
        assert again == cfg


class TestScalarizers:
    def test_weighted_objective(self):
        sc = weighted_objective({"a": 1.0, "b": 2.0})
        m = sc({"a": PerfMetric(10.0), "b": PerfMetric(3.0, False)}, {})
        # a maximizes (objective -10), b minimizes (objective 3)
        assert m.value == pytest.approx(-10.0 + 2.0 * 3.0)
        assert not m.higher_is_better

    def test_throughput_under_sla(self):
        sc = throughput_under_sla("srv", sla_s=1.0, penalty=2.0)
        ok = sc({"srv": PerfMetric(100.0, metrics={"latency_s": 0.5})}, {})
        assert ok.value == 100.0 and ok.metrics["sla_met"]
        slow = sc({"srv": PerfMetric(100.0, metrics={"latency_s": 2.0})}, {})
        assert slow.value == pytest.approx(25.0)
        assert not slow.metrics["sla_met"]

    def test_throughput_under_sla_requires_latency_metric(self):
        """A missing latency measurement must error, not silently drop the
        SLA constraint from the whole search."""
        sc = throughput_under_sla("srv", sla_s=1.0)
        with pytest.raises(ValueError, match="latency"):
            sc({"srv": PerfMetric(100.0)}, {})


def _composed_sut():
    return CompositeSUT(
        {"db": MySQLSurrogate(), "fe": FrontendSurrogate()},
        weighted_objective({"db": 1.0, "fe": 1.0}))


class TestCompositeSUT:
    def test_shared_budget_and_single_dispatch(self):
        """Acceptance criterion: batched composite rounds dispatch as single
        test_batch calls — one tuner evaluator call and one call per member
        per round, never per config."""
        sut = _composed_sut()
        budget = 120
        tuner = Tuner(sut.space(), sut, budget=budget, seed=0)
        assert tuner.batch  # auto-detected BatchEvaluator
        rep = tuner.run()
        assert rep.n_tests == budget  # ONE shared resource limit
        assert tuner.n_evaluator_calls < budget / 5
        for name in sut.members:
            assert sut.member_batch_calls[name] == tuner.n_evaluator_calls
            assert sut.member_test_calls[name] == 0

    @pytest.mark.parametrize("optimizer", ["rrs", "subspace_rr"])
    @pytest.mark.parametrize("seed", [0, 3])
    def test_batched_sequential_parity(self, optimizer, seed):
        """Same seed => identical trial sequence through CompositeSUT in
        both dispatch modes."""
        runs = []
        for batch in (True, False):
            sut = _composed_sut()
            tuner = Tuner(sut.space(), sut, budget=90, seed=seed,
                          optimizer=optimizer, batch=batch)
            runs.append(tuner.run())
        rb, rs = runs
        assert rb.best_config == rs.best_config
        assert rb.best_metric.value == rs.best_metric.value
        assert rb.n_tests == rs.n_tests
        assert [t.config for t in rb.history] == \
               [t.config for t in rs.history]
        assert [t.value for t in rb.history] == \
               [t.value for t in rs.history]

    def test_member_values_reported(self):
        sut = _composed_sut()
        m = sut.test(sut.space().default_config())
        assert set(m.metrics["member_values"]) == {"db", "fe"}

    def test_config_only_member_never_evaluated(self):
        """A bare ParameterSpace member contributes knobs + scalarizer
        visibility but no standalone evaluation."""
        knob_only = ParameterSpace([IntParam("k", 1, 9, default=5)])
        seen = []

        def scalarize(metrics, configs):
            seen.append((set(metrics), configs["cfg"]["k"]))
            return PerfMetric(metrics["db"].value * configs["cfg"]["k"])

        sut = CompositeSUT({"db": MySQLSurrogate(), "cfg": knob_only},
                           scalarize)
        assert sut.space().dim == MySQLSurrogate().space().dim + 1
        m = sut.test(sut.space().default_config())
        assert seen[0][0] == {"db"}  # no metric for the config-only member
        assert seen[0][1] == 5
        assert set(m.metrics["member_values"]) == {"db"}
        assert "cfg" not in sut.member_batch_calls


class TestSubspaceRoundRobin:
    def test_registered(self):
        assert isinstance(get_optimizer("subspace_rr"),
                          SubspaceRoundRobinOptimizer)

    def test_budget_respected_and_monotone(self):
        space = ParameterSpace(
            [FloatParam(f"x{i}", -5.0, 5.0, default=4.0) for i in range(4)])
        calls = []

        def obj(cfg):
            calls.append(1)
            return sum(v * v for v in cfg.values())

        res = SubspaceRoundRobinOptimizer().optimize(
            space, obj, budget=80, rng=np.random.default_rng(0))
        assert len(calls) == 80 == res.n_tests
        trace = res.best_so_far()
        assert all(a >= b for a, b in zip(trace, trace[1:]))
        assert res.best_value < 4 * 16.0  # improved over the corner default

    def test_round_robin_varies_one_subspace_per_round(self):
        a, b = _toy_spaces()
        cs = CompositeSpace({"a": a, "b": b})
        seen_rounds = []

        def batch_obj(cfgs):
            seen_rounds.append(cfgs)
            return [abs(c["a.x"] - 0.3) + abs(c["b.m"] - 51) / 50
                    for c in cfgs]

        SubspaceRoundRobinOptimizer(round_size=5).optimize(
            cs, None, budget=60, rng=np.random.default_rng(0),
            batch_objective=batch_obj)
        # every exploit round (size round_size) pins all but one subspace
        for cfgs in seen_rounds:
            if len(cfgs) != 5:
                continue  # explore/diverge round
            varies_a = len({(c["a.x"], c["a.n"]) for c in cfgs}) > 1
            varies_b = len({c["b.m"] for c in cfgs}) > 1
            assert not (varies_a and varies_b)

    def test_bad_params_rejected(self):
        with pytest.raises(ValueError):
            SubspaceRoundRobinOptimizer(round_size=0)
        with pytest.raises(ValueError):
            SubspaceRoundRobinOptimizer(shrink=1.0)


class TestCotuneSurrogate:
    def test_deterministic_and_coupled(self):
        """The serve optimum must move with the kernel block choice — the
        co-deployment interaction the joint mode exists for."""
        p = CotuneParams()
        space = serve_knob_space(p.max_seq)
        base = space.default_config()

        def best_batch(kernel_cfg):
            vals = {}
            for B in (4, 8, 12, 16, 24, 32):
                cfg = dict(base, max_batch=B,
                           kv_cache_pages=max(space["kv_cache_pages"].lo,
                                              B * p.max_seq // 16))
                vals[B] = coupled_serve_metrics(cfg, kernel_cfg, p).value
            return max(vals, key=vals.get)

        slow = best_batch({"block_kv": 64})
        fast = best_batch({"block_kv": 1024})
        assert fast > slow  # faster kernel => larger optimal batch
        # determinism (batched parity depends on it)
        m1 = coupled_serve_metrics(base, {"block_kv": 256}, p)
        m2 = coupled_serve_metrics(dict(base), {"block_kv": 256}, p)
        assert m1.value == m2.value

    def test_joint_beats_independent_at_equal_budget(self):
        """The tentpole claim, in miniature (single seed, the benchmark
        budget — the continuous-runtime recalibration flattened the
        surrogate's optimum, so starved budgets are coin-flips between
        arms; the 3-seed mean at this budget is the CI gate).  The budget
        scales with the knob space: share_prefix/draft_len widened the
        serve space, and 96 trials over the joint product became a
        coin-flip again — 160 wins on every seed."""
        from repro.autotune.sut import KernelSUT

        p = CotuneParams()
        budget, seed = 160, 0
        half = budget // 2
        krep = Tuner(KernelSUT("decode_attention", p.decode_dims(8),
                               dtype=p.dtype, mode="model").space(),
                     KernelSUT("decode_attention", p.decode_dims(8),
                               dtype=p.dtype, mode="model"),
                     budget=half, seed=seed).run()
        srep = Tuner(serve_knob_space(p.max_seq), ServeSurrogate(p),
                     budget=budget - half, seed=seed).run()
        indep = coupled_serve_metrics(srep.best_config, krep.best_config, p)

        sut = make_cotune_sut(p)
        jrep = Tuner(sut.space(), sut, budget=budget, seed=seed,
                     optimizer="subspace_rr").run()
        parts = sut.space().split(jrep.best_config)
        joint = coupled_serve_metrics(parts["serve"], parts["kernel"], p)
        # minimized objective: joint <= independent
        assert joint.objective() <= indep.objective()

    def test_cotune_parity_through_composite(self):
        """Same seed => identical trial sequence batched vs sequential
        through the full serve+kernel CompositeSUT."""
        p = CotuneParams()
        reps = []
        for batch in (True, False):
            sut = make_cotune_sut(p)
            reps.append(Tuner(sut.space(), sut, budget=50, seed=2,
                              optimizer="subspace_rr", batch=batch).run())
        rb, rs = reps
        assert [t.config for t in rb.history] == \
               [t.config for t in rs.history]
        assert rb.best_metric.value == rs.best_metric.value

    def test_serve_config_knob_application(self):
        from repro.serve.space import apply_serve_knobs

        cfg = apply_serve_knobs({"max_batch": 4, "prefill_chunk": 256,
                                 "kv_cache_pages": 2048,
                                 "schedule": "sjf"})
        assert cfg.batch_slots == 4
        assert cfg.prefill_chunk == 256
        assert cfg.kv_cache_pages == 2048
        assert cfg.schedule == "sjf"

    def test_tuned_knobs_always_deploy(self):
        """The tuner legitimately explores undersized KV caches (scored as
        thrash); applying such a winner must raise the pages to the floor
        the deployed batch requires, not crash."""
        from repro.serve.space import PAGE_TOKENS, apply_serve_knobs

        cfg = apply_serve_knobs({"max_batch": 64, "prefill_chunk": 512,
                                 "kv_cache_pages": 128,
                                 "schedule": "fifo"})
        assert cfg.kv_cache_pages * PAGE_TOKENS >= 64 * cfg.max_seq

    def test_serve_config_validation(self):
        from repro.serve import ServeConfig

        with pytest.raises(ValueError, match="KV cache too small"):
            ServeConfig(max_seq=2048, batch_slots=8, kv_cache_pages=512)
        with pytest.raises(ValueError, match="unknown schedule"):
            ServeConfig(schedule="lifo")
        # unset pages auto-size to the slots x seq footprint at ANY shape
        from repro.serve.space import PAGE_TOKENS

        big = ServeConfig(max_seq=4096, batch_slots=32)
        assert big.kv_cache_pages * PAGE_TOKENS >= 32 * 4096
