"""Continuous-batching runtime: schedule/layout parity, paging invariants,
per-request provenance, and surrogate↔runtime rank agreement.

The central contract: the tuned knobs (`schedule`, `kv_cache_pages`,
`prefill_chunk`, `max_batch`) move *when* work happens — never *what* is
generated.  Every request's tokens must be identical across schedules,
KV layouts and slot placements, pinned here at token level against the
wave runtime's stepwise-forward oracle lineage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ModelConfig
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine
from repro.serve.paging import PAGE_TOKENS

TINY = ModelConfig(
    name="tiny-lm", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", vocab_pad_multiple=64,
    rope_theta=10_000.0,
)

PROMPTS = [[1, 2, 3, 4, 5], [9, 8, 7, 6, 5], [2, 2, 2],
           [7, 1, 4, 1, 5, 9, 2, 6], [3, 3], [5, 4, 3, 2, 1, 6]]
MAX_NEW = [6, 3, 5, 2, 7, 4]


@pytest.fixture(scope="module")
def engine():
    model = Model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _cfg(**kw):
    base = dict(max_seq=32, batch_slots=2, runtime="continuous",
                prefill_chunk=4)
    base.update(kw)
    return ServeConfig(**base)


@pytest.fixture(scope="module")
def reference_tokens(engine):
    """Oracle continuations: wave runtime, one request per wave."""
    model, params = engine
    eng = ServeEngine(model, params, ServeConfig(
        max_seq=32, batch_slots=1, runtime="wave"))
    return [eng.generate([p], m).tokens[0]
            for p, m in zip(PROMPTS, MAX_NEW)]


class TestScheduleParity:
    @pytest.mark.parametrize("layout", ["dense", "paged"])
    def test_tokens_identical_across_schedules(self, engine, layout,
                                               reference_tokens):
        model, params = engine
        outs = {}
        for sched in ("fifo", "sjf", "interleave"):
            eng = ServeEngine(model, params,
                              _cfg(kv_layout=layout, schedule=sched))
            outs[sched] = eng.generate(PROMPTS, MAX_NEW).tokens
        assert outs["fifo"] == outs["sjf"] == outs["interleave"]
        # ... and identical to the one-request-per-wave oracle: admission
        # order, slot placement and pool layout never touch token values
        assert outs["fifo"] == reference_tokens

    def test_paged_vs_dense_parity(self, engine):
        model, params = engine
        dense = ServeEngine(model, params, _cfg(kv_layout="dense"))
        paged = ServeEngine(model, params, _cfg(kv_layout="paged"))
        assert dense.generate(PROMPTS, MAX_NEW).tokens == \
            paged.generate(PROMPTS, MAX_NEW).tokens

    def test_slot_count_invariance(self, engine, reference_tokens):
        """More slots change concurrency, not content."""
        model, params = engine
        for slots in (1, 3):
            eng = ServeEngine(model, params, _cfg(batch_slots=slots,
                                                  kv_layout="paged"))
            assert eng.generate(PROMPTS, MAX_NEW).tokens == reference_tokens

    def test_eos_frees_slot_early(self, engine):
        model, params = engine
        probe = ServeEngine(model, params, _cfg(batch_slots=1))
        eos = probe.generate([[3, 1, 4]], 1).tokens[0][0]
        eng = ServeEngine(model, params, _cfg(
            batch_slots=1, eos_token=int(eos)))
        res = eng.generate([[3, 1, 4], [1, 2, 3, 4]], [8, 2])
        assert res.tokens[0] == [eos]
        assert len(res.tokens[1]) == 2


class TestPagingRuntime:
    def test_no_page_leaks_after_mixed_run(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, _cfg(kv_layout="paged",
                                              batch_slots=3))
        eng.generate(PROMPTS, MAX_NEW)
        alloc = eng.last_alloc
        assert alloc is not None
        assert alloc.groups_in_use == 0  # every completion released
        assert alloc.high_water > 0
        alloc.check_balanced()

    def test_small_pool_bounds_concurrency_not_tokens(self, engine,
                                                      reference_tokens):
        """A pool big enough for ~one request serializes admission (the
        real memory/throughput trade-off) but generates the same tokens —
        and needs more decode steps at the same token count (occupancy
        collapses: the noise-free throughput signal)."""
        model, params = engine
        small = ServeEngine(model, params, _cfg(
            kv_layout="paged", batch_slots=3, kv_cache_pages=3))
        big = ServeEngine(model, params, _cfg(
            kv_layout="paged", batch_slots=3))
        rs, rb = (e.generate(PROMPTS, MAX_NEW) for e in (small, big))
        assert rs.tokens == rb.tokens == reference_tokens
        assert sum(len(t) for t in rs.tokens) == sum(len(t) for t in rb.tokens)
        assert rs.steps > rb.steps
        assert small.last_alloc.high_water <= 2

    def test_undersized_pool_rejected_at_config(self):
        with pytest.raises(ValueError, match="KV cache too small"):
            ServeConfig(max_seq=64, runtime="continuous", kv_layout="paged",
                        kv_cache_pages=2)

    def test_unknown_runtime_and_layout_rejected(self):
        with pytest.raises(ValueError, match="unknown runtime"):
            ServeConfig(runtime="batch")
        with pytest.raises(ValueError, match="unknown kv_layout"):
            ServeConfig(kv_layout="ring")

    def test_grouped_pool_layout(self, engine, reference_tokens):
        """kv_page_block > 1 (the paged kernel's pages_per_block tile as
        allocator granularity) coarsens groups without touching tokens."""
        model, params = engine
        eng = ServeEngine(model, params, _cfg(
            kv_layout="paged", kv_page_block=2))
        assert eng.group_tokens == 2 * PAGE_TOKENS
        assert eng.generate(PROMPTS, MAX_NEW).tokens == reference_tokens

    def test_recurrent_stack_falls_back_to_wave(self):
        from repro.configs import get_config, reduced

        cfg = reduced(get_config("zamba2-1.2b"))
        model = Model(cfg)
        assert not model.supports_continuous_batching
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=1, runtime="continuous"))
        assert not eng._continuous
        res = eng.generate([[1, 2, 3, 4, 5]], 2)
        assert len(res.tokens[0]) == 2


class TestPagePolicy:
    """The tuned page_policy axis: on_demand admits on prompt-size
    reservations, grows them per step, and preempts (recompute) on pool
    exhaustion — identical tokens, strictly better packing on
    oversubscribed pools."""

    # decode-heavy mixed workload: worst-case footprints of 2 groups per
    # request at PAGE_TOKENS=16, so a 4-page pool (3 usable groups)
    # serializes reserve admission but packs 3 on_demand prompts
    HEAVY_PROMPTS = [[1, 2, 3], [9, 8, 7, 6], [2, 2, 2, 2, 2],
                     [7, 1, 4, 1], [3, 3, 3], [5, 4, 3, 2, 1, 6]]
    HEAVY_NEW = [14, 12, 16, 13, 18, 12]

    def _run(self, engine, policy, pages=4, slots=3, **kw):
        model, params = engine
        eng = ServeEngine(model, params, _cfg(
            kv_layout="paged", batch_slots=slots, kv_cache_pages=pages,
            page_policy=policy, **kw))
        res = eng.generate(self.HEAVY_PROMPTS, self.HEAVY_NEW)
        eng.last_alloc.check_balanced()
        assert eng.last_alloc.groups_in_use == 0
        return res

    def test_forced_preemption_token_parity(self, engine):
        """Preemption re-prefills prompt+generated and continues at the
        same (rid, token-index) keys: bit-identical tokens, fewer decode
        steps (better packing) on the oversubscribed pool."""
        reserve = self._run(engine, "reserve")
        on_demand = self._run(engine, "on_demand")
        assert on_demand.preemptions > 0  # the pool really ran dry
        assert reserve.preemptions == 0   # reserve can never preempt
        assert on_demand.tokens == reserve.tokens
        assert on_demand.steps < reserve.steps
        # per-request provenance carries the recompute count
        assert sum(r["preemptions"] for r in on_demand.per_request) \
            == on_demand.preemptions

    def test_policy_parity_across_schedules(self, engine):
        outs = [self._run(engine, "on_demand", schedule=s).tokens
                for s in ("fifo", "sjf", "interleave")]
        assert outs[0] == outs[1] == outs[2]

    def test_on_demand_temperature_parity(self, engine):
        """Sampled tokens survive preemption bit-identically: the
        (rid, token-index) key stream is recomputed, not resumed."""
        outs = {}
        for pol in ("reserve", "on_demand"):
            outs[pol] = self._run(engine, pol, temperature=0.8, seed=7)
        assert outs["on_demand"].preemptions > 0
        assert outs["on_demand"].tokens == outs["reserve"].tokens

    def test_on_demand_inert_on_big_pools(self, engine):
        """With every worst case resident the policies are identical:
        no extension failures, no preemptions, same step count."""
        reserve = self._run(engine, "reserve", pages=16)
        on_demand = self._run(engine, "on_demand", pages=16)
        assert on_demand.preemptions == 0
        assert on_demand.tokens == reserve.tokens
        assert on_demand.steps == reserve.steps

    def test_dense_layout_ignores_policy(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, _cfg(kv_layout="dense",
                                              page_policy="on_demand"))
        res = eng.generate(self.HEAVY_PROMPTS, self.HEAVY_NEW)
        assert res.preemptions == 0

    def test_unknown_page_policy_rejected(self):
        with pytest.raises(ValueError, match="unknown page_policy"):
            ServeConfig(page_policy="lazy")

    def test_error_path_releases_pages(self, engine):
        """Regression: an exception mid-generation (e.g. inside a decode
        dispatch) must unwind every live reservation — a stranded page
        group would silently shrink every later run's pool."""
        model, params = engine
        for policy in ("reserve", "on_demand"):
            eng = ServeEngine(model, params, _cfg(
                kv_layout="paged", batch_slots=3, page_policy=policy))

            calls = {"n": 0}
            real = eng._decode_multi

            def boom(*a, _real=real, **kw):
                calls["n"] += 1
                if calls["n"] >= 3:  # fail mid-flight, with live slots
                    raise RuntimeError("injected decode failure")
                return _real(*a, **kw)

            eng._decode_multi = boom
            with pytest.raises(RuntimeError, match="injected"):
                eng.generate(self.HEAVY_PROMPTS, self.HEAVY_NEW)
            assert eng.last_alloc is not None
            assert eng.last_alloc.groups_in_use == 0
            eng.last_alloc.check_balanced()

    def test_sjf_bypass_beats_head_of_line_blocking(self, engine):
        """A blocked sjf head (reservation too big for the free pool)
        must not starve a smaller pending request that fits: the bounded
        bypass admits it, so it starts BEFORE the policy-earlier blocked
        request.  fifo stays strict (arrival order of first tokens)."""
        model, params = engine
        prompts = [[1, 2, 3, 4], [9, 8, 7, 6, 5], [2, 4, 6, 8, 1, 3]]
        max_new = [28, 27, 6]  # worst-case groups: 2, 2, 1 (4-page pool)
        ttft = {}
        for sched in ("sjf", "fifo"):
            eng = ServeEngine(model, params, _cfg(
                kv_layout="paged", batch_slots=2, kv_cache_pages=4,
                schedule=sched))
            res = eng.generate(prompts, max_new)
            ttft[sched] = [r["ttft_s"] for r in res.per_request]
        # sjf: rid 2 bypasses blocked rid 1 and decodes alongside rid 0
        assert ttft["sjf"][2] < ttft["sjf"][1]
        # fifo keeps strict admission order
        assert ttft["fifo"][1] < ttft["fifo"][2]


class TestPerRequestStats:
    def test_provenance_shape_and_ordering(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, _cfg(kv_layout="paged"))
        res = eng.generate(PROMPTS, MAX_NEW)
        assert [r["rid"] for r in res.per_request] == list(range(len(PROMPTS)))
        for r, p, m, t in zip(res.per_request, PROMPTS, MAX_NEW, res.tokens):
            assert r["prompt_len"] == len(p)
            assert r["new_tokens"] == len(t) <= m
            assert 0 < r["ttft_s"] <= r["latency_s"]
        assert res.p50_latency_s <= res.p95_latency_s
        assert res.decode_tokens_per_sec > 0

    def test_wave_runtime_also_reports(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=2, runtime="wave"))
        res = eng.generate([[1, 2, 3]] * 5, 3)
        assert len(res.per_request) == 5
        assert all(r["latency_s"] > 0 for r in res.per_request)
        # wave w+1 finishes after wave w
        lats = [r["latency_s"] for r in res.per_request]
        assert lats == sorted(lats)

    def test_temperature_sampling_schedule_invariant(self, engine):
        """Sampled (non-greedy) tokens key on (request id, token index)
        only, so they too are identical across schedules."""
        model, params = engine
        outs = {}
        for sched in ("fifo", "sjf"):
            eng = ServeEngine(model, params, _cfg(
                schedule=sched, temperature=0.8, seed=7))
            outs[sched] = eng.generate(PROMPTS, MAX_NEW).tokens
        assert outs["fifo"] == outs["sjf"]


class TestSurrogateRankAgreement:
    """Satellite: the analytic surrogate's schedule/paging terms are
    re-derived from the real scheduler; pin that both rank configs the
    same way, on the runtime's noise-free counters where possible."""

    def _surrogate(self, schedule, pages, p=None, policy="reserve"):
        from repro.serve.space import (CotuneParams, coupled_serve_metrics,
                                       serve_knob_space)

        p = p or CotuneParams(prompt_len=64, gen_len=16, max_seq=256,
                              n_requests=16)
        cfg = serve_knob_space(p.max_seq).default_config()
        cfg["schedule"] = schedule
        cfg["kv_cache_pages"] = pages
        cfg["page_policy"] = policy
        kcfg = p.default_kernel_config()
        return coupled_serve_metrics(cfg, kcfg, p)

    def test_pages_rank_agreement(self, engine):
        """Fewer pages => fewer resident requests => lower throughput.
        Engine evidence: decode-step count at equal tokens (deterministic);
        surrogate evidence: the value ordering."""
        model, params = engine
        steps = {}
        for pages in (3, 8):
            eng = ServeEngine(model, params, _cfg(
                kv_layout="paged", batch_slots=3, kv_cache_pages=pages))
            steps[pages] = eng.generate(PROMPTS, MAX_NEW).steps
        assert steps[3] > steps[8]  # engine: small pool => low occupancy
        lo = self._surrogate("fifo", pages=2)
        hi = self._surrogate("fifo", pages=16)
        assert lo.value < hi.value  # surrogate ranks the same way
        assert lo.metrics["resident"] < hi.metrics["resident"]

    def test_page_policy_rank_agreement(self, engine):
        """Engine evidence (noise-free decode-step counts, pinned above in
        TestPagePolicy): on_demand completes equal tokens in fewer steps
        on an oversubscribed pool.  The surrogate must rank the same way —
        and flip on big pools, where on_demand only pays bookkeeping: the
        policy optimum genuinely shifts with kv_cache_pages."""
        from repro.serve.space import CotuneParams

        # decode-heavy workload: expected footprint (prompt+gen/2) is
        # well under the worst case, which is where on_demand packs
        p = CotuneParams(prompt_len=32, gen_len=96, max_seq=256,
                         n_requests=16)
        small_od = self._surrogate("fifo", pages=21, p=p, policy="on_demand")
        small_rs = self._surrogate("fifo", pages=21, p=p, policy="reserve")
        assert small_od.value > small_rs.value
        assert small_od.metrics["resident"] > small_rs.metrics["resident"]
        assert small_od.metrics["preempt_frac"] > 0  # the recompute tax
        big_od = self._surrogate("fifo", pages=256, p=p, policy="on_demand")
        big_rs = self._surrogate("fifo", pages=256, p=p, policy="reserve")
        assert big_rs.value > big_od.value  # bookkeeping, no packing gain
        assert big_od.metrics["preempt_frac"] == 0
        assert big_od.metrics["resident"] == big_rs.metrics["resident"]

    def test_sjf_rank_agreement_on_mean_latency(self, engine):
        """One long prompt ahead of short ones on a single slot: sjf must
        cut MEAN latency vs fifo in the real engine, as the surrogate's
        sjf term claims.  Throughput (total tokens/steps) stays equal."""
        model, params = engine
        prompts = [[7] * 24] + [[i + 1, 2, 3] for i in range(4)]
        max_new = [4] * 5
        res = {}
        for sched in ("fifo", "sjf"):
            eng = ServeEngine(model, params, ServeConfig(
                max_seq=32, batch_slots=1, runtime="continuous",
                schedule=sched, prefill_chunk=8))
            res[sched] = eng.generate(prompts, max_new)
        mean = {s: np.mean([r["latency_s"] for r in res[s].per_request])
                for s in res}
        assert mean["sjf"] < mean["fifo"]
        assert res["sjf"].steps == res["fifo"].steps
        s_f = self._surrogate("fifo", pages=64)
        s_s = self._surrogate("sjf", pages=64)
        assert s_s.metrics["latency_s"] < s_f.metrics["latency_s"]
        assert s_s.metrics["raw_throughput"] == s_f.metrics["raw_throughput"]

    def test_interleave_rank_agreement_on_overlap(self, engine):
        """Interleave keeps decoding while admissions prefill: the engine
        must issue decode steps *between* a long admission's chunks (fifo
        cannot), matching the surrogate's overlapped-prefill term."""
        model, params = engine
        prompts = [[1, 2, 3], [9] * 24]
        max_new = [12, 2]
        steps = {}
        for sched in ("fifo", "interleave"):
            eng = ServeEngine(model, params, ServeConfig(
                max_seq=32, batch_slots=2, runtime="continuous",
                schedule=sched, prefill_chunk=4))
            steps[sched] = eng.generate(prompts, max_new)
        assert steps["interleave"].tokens == steps["fifo"].tokens
        s_f = self._surrogate("fifo", pages=64)
        s_i = self._surrogate("interleave", pages=64)
        # at C>1 the surrogate charges prefill once (overlapped) instead of
        # per-admission: interleave >= fifo on raw throughput
        assert s_i.metrics["raw_throughput"] >= s_f.metrics["raw_throughput"]


# 18-token common prefix (one full 16-token page group) + distinct tails:
# the repeated-system-prompt workload prefix sharing exists for.
SHARED_PREFIX = [7, 3, 9, 1, 4, 4, 8, 2, 6, 5, 1, 9, 2, 7, 3, 8, 5, 2]
SHARED_PROMPTS = [SHARED_PREFIX + [11], SHARED_PREFIX + [12, 13],
                  SHARED_PREFIX + [14, 15, 16], SHARED_PREFIX + [17]]
SHARED_NEW = [5, 4, 6, 3]


class TestPrefixSharing:
    """The CoW prefix-sharing tentpole: identical tokens, fewer prefill
    dispatches, zero page leaks — under forced copy-on-write splits and
    sharer preemptions."""

    def _run(self, engine, share, max_new=None, **kw):
        model, params = engine
        eng = ServeEngine(model, params, _cfg(
            max_seq=64, kv_layout="paged", share_prefix=share, **kw))
        res = eng.generate(SHARED_PROMPTS, max_new or SHARED_NEW)
        eng.last_alloc.check_balanced()
        assert eng.last_alloc.groups_in_use == 0
        return res

    def test_sharing_token_parity_and_fewer_prefill_chunks(self, engine):
        off = self._run(engine, False)
        on = self._run(engine, True)
        assert on.tokens == off.tokens  # sharing moves work, not content
        assert on.shared_prefix_tokens > 0
        assert off.shared_prefix_tokens == 0
        # the shared groups' prefill was genuinely skipped
        assert on.prefill_chunks < off.prefill_chunks
        # per-request provenance carries the shared-token counts
        assert sum(r["shared_tokens"] for r in on.per_request) \
            == on.shared_prefix_tokens
        assert any(r["shared_tokens"] == 0 for r in on.per_request)  # donor

    def test_sharing_parity_across_schedules(self, engine):
        outs = [self._run(engine, True, schedule=s).tokens
                for s in ("fifo", "sjf", "interleave")]
        assert outs[0] == outs[1] == outs[2]

    def test_sharing_temperature_parity(self, engine):
        """Sampled tokens key on (rid, token index) only — admitting from
        shared groups must not shift the key stream."""
        off = self._run(engine, False, temperature=0.8, seed=7)
        on = self._run(engine, True, temperature=0.8, seed=7)
        assert on.tokens == off.tokens
        assert on.shared_prefix_tokens > 0

    def test_forced_cow_split_preserves_tokens(self, engine):
        """An identical prompt and a boundary-sharing shorter prompt both
        cover into their final token's group: the engine must CoW-split
        that group before the first divergent write, leaving the donor's
        KV bytes untouched (pinned via the donor's own continuation)."""
        model, params = engine
        donor = [((i * 37) % 509) + 1 for i in range(32)]  # 2 full groups
        # the donor decodes long enough to stay resident (groups live,
        # registry fresh) while the filler drains a slot and each sharer
        # is admitted in turn; both sharers' coverage ends mid-group
        # (identical prompt: 31 of 32 — the last token always dispatches
        # for logits; boundary prompt: 19 of 20), forcing a CoW split
        prompts = [donor, [1, 2, 3], list(donor), donor[:20]]
        max_new = [26, 2, 5, 4]
        outs = {}
        for share in (False, True):
            eng = ServeEngine(model, params, _cfg(
                max_seq=64, batch_slots=2, kv_layout="paged",
                share_prefix=share))
            outs[share] = eng.generate(prompts, max_new)
            eng.last_alloc.check_balanced()
            assert eng.last_alloc.groups_in_use == 0
        assert outs[True].tokens == outs[False].tokens
        assert outs[True].cow_splits >= 2  # both sharers forced a split
        assert outs[True].shared_prefix_tokens > 0
        assert outs[True].prefill_chunks < outs[False].prefill_chunks

    def test_sharing_survives_preemption_and_cuts_recompute(self, engine):
        """on_demand exhaustion on a shared workload: shared groups stay
        resident through a sharer's preemption (other owners hold them),
        so readmission re-prefills only the private tail — same tokens,
        fewer prefill dispatches than the unshared run."""
        outs = {}
        for share in (False, True):
            # decode-heavy on a 4-usable-group pool: requests outgrow
            # their prompt-size reservations mid-decode and run it dry
            # even with the shared prefix deduplicated
            outs[share] = self._run(engine, share, batch_slots=3,
                                    kv_cache_pages=5,
                                    page_policy="on_demand",
                                    max_new=[14, 13, 16, 12])
        assert outs[True].tokens == outs[False].tokens
        assert outs[True].preemptions > 0  # the pool really ran dry
        assert outs[True].prefill_chunks < outs[False].prefill_chunks

    def test_sharing_inert_on_dense_layout(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, _cfg(
            max_seq=64, kv_layout="dense", share_prefix=True))
        res = eng.generate(SHARED_PROMPTS, SHARED_NEW)
        assert res.shared_prefix_tokens == 0 and res.cow_splits == 0


class TestSpeculativeDecode:
    """Self-speculative n-gram decoding: the draft rides the SAME verify
    dispatch and the acceptance rule replays greedy/sampled choices at
    the same (rid, token-index) keys — so tokens are bit-identical at any
    draft_len, and repetitive histories collapse dispatch counts."""

    def test_draft_parity_matrix(self, engine, reference_tokens):
        model, params = engine
        for k in (2, 4):
            for sched in ("fifo", "sjf", "interleave"):
                eng = ServeEngine(model, params, _cfg(
                    kv_layout="paged", schedule=sched, draft_len=k))
                res = eng.generate(PROMPTS, MAX_NEW)
                assert res.tokens == reference_tokens, (k, sched)

    def test_draft_parity_dense_layout(self, engine, reference_tokens):
        model, params = engine
        eng = ServeEngine(model, params, _cfg(kv_layout="dense",
                                              draft_len=4))
        assert eng.generate(PROMPTS, MAX_NEW).tokens == reference_tokens

    def test_draft_parity_under_preemption(self, engine):
        """Speculation composes with on_demand growth/preemption: the
        draft-aware pre-extension and recompute keep token parity."""
        model, params = engine
        outs = {}
        for k in (0, 4):
            eng = ServeEngine(model, params, _cfg(
                kv_layout="paged", batch_slots=3, kv_cache_pages=4,
                page_policy="on_demand", draft_len=k))
            outs[k] = eng.generate(TestPagePolicy.HEAVY_PROMPTS,
                                   TestPagePolicy.HEAVY_NEW)
            eng.last_alloc.check_balanced()
            assert eng.last_alloc.groups_in_use == 0
        assert outs[4].tokens == outs[0].tokens
        assert outs[4].preemptions > 0

    def test_draft_temperature_parity(self, engine):
        model, params = engine
        outs = {}
        for k in (0, 4):
            eng = ServeEngine(model, params, _cfg(
                kv_layout="paged", temperature=0.8, seed=7, draft_len=k))
            outs[k] = eng.generate(PROMPTS, MAX_NEW).tokens
        assert outs[4] == outs[0]

    def test_acceptance_collapses_dispatches_on_repetitive_history(
            self, engine):
        """The acceptance machinery itself, pinned on a constant-output
        model (zeroed params -> uniform logits -> greedy repeats token 0):
        the n-gram draft matches the generated loop, verification accepts
        it, and equal tokens arrive in strictly fewer dispatches."""
        model, params = engine
        zero = jax.tree_util.tree_map(jnp.zeros_like, params)
        runs = {}
        for k in (0, 4):
            eng = ServeEngine(model, zero, _cfg(kv_layout="paged",
                                                draft_len=k))
            runs[k] = eng.generate([[5, 3, 5, 3]], 12)
        assert runs[4].tokens == runs[0].tokens
        assert runs[4].drafted > 0
        assert runs[4].accepted > 0
        assert runs[4].steps < runs[0].steps
        assert 0.0 < runs[4].acceptance_rate <= 1.0
        assert runs[0].drafted == runs[0].accepted == 0

    def test_sharing_and_speculation_compose(self, engine):
        model, params = engine
        outs = {}
        for on in (False, True):
            eng = ServeEngine(model, params, _cfg(
                max_seq=64, kv_layout="paged", share_prefix=on,
                draft_len=4 if on else 0))
            outs[on] = eng.generate(SHARED_PROMPTS, SHARED_NEW)
            eng.last_alloc.check_balanced()
            assert eng.last_alloc.groups_in_use == 0
        assert outs[True].tokens == outs[False].tokens
        assert outs[True].shared_prefix_tokens > 0

    def test_negative_draft_len_rejected(self):
        with pytest.raises(ValueError, match="draft_len"):
            _cfg(draft_len=-1)

    def test_new_knob_surrogate_rank_agreement(self, engine):
        """Engine evidence (prefill_chunks / dispatch counts above) says
        sharing and accepted speculation do strictly less work for equal
        tokens; the surrogate must rank the widened knob space the same
        way — and must rank speculation WORSE when nothing is accepted."""
        from repro.serve.space import CotuneParams, coupled_serve_metrics

        p = CotuneParams(prompt_len=64, gen_len=16, max_seq=256,
                         n_requests=16)
        kcfg = p.default_kernel_config()
        base = dict(max_batch=8, prefill_chunk=64, kv_cache_pages=64,
                    schedule="fifo", page_policy="reserve")
        v0 = coupled_serve_metrics(dict(base), kcfg, p)
        vs = coupled_serve_metrics(dict(base, share_prefix=1), kcfg, p)
        vk = coupled_serve_metrics(dict(base, draft_len=4), kcfg, p)
        assert vs.value > v0.value
        assert vs.metrics["prefill_s"] < v0.metrics["prefill_s"]
        assert vk.value > v0.value
        assert vk.metrics["spec_tokens_per_step"] > 1.0
        # zero acceptance: drafts are pure verify overhead
        p_dry = CotuneParams(prompt_len=64, gen_len=16, max_seq=256,
                             n_requests=16, spec_accept=0.0)
        vk_dry = coupled_serve_metrics(dict(base, draft_len=4), kcfg, p_dry)
        v0_dry = coupled_serve_metrics(dict(base), kcfg, p_dry)
        assert vk_dry.value < v0_dry.value
