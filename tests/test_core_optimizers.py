"""Tests for RRS and baseline optimizers: the paper's three optimizer
conditions (§4.1) — works at any budget, improves with budget, escapes
local optima."""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    FloatParam,
    ParameterSpace,
    RRSOptimizer,
    get_optimizer,
    OPTIMIZERS,
)


def sphere_space(dim=6):
    return ParameterSpace(
        [FloatParam(f"x{i}", -5.0, 5.0, default=4.0) for i in range(dim)]
    )


def sphere(cfg):
    return sum(v * v for v in cfg.values())


def rastrigin(cfg):
    xs = list(cfg.values())
    return 10 * len(xs) + sum(x * x - 10 * math.cos(2 * math.pi * x) for x in xs)


class TestRRS:
    def test_confidence_sample_counts(self):
        rrs = RRSOptimizer(p=0.99, r=0.1)
        # n = ln(0.01)/ln(0.9) = 43.7 -> 44
        assert rrs.n_explore == 44
        assert RRSOptimizer(p=0.99, r=0.1, q=0.99, v=0.8).n_exploit == 3

    @given(budget=st.integers(min_value=1, max_value=40))
    @settings(max_examples=20, deadline=None)
    def test_any_budget_returns_answer(self, budget):
        """Condition (1): an answer at any sample-set size, budget respected."""
        space = sphere_space(4)
        calls = []

        def obj(cfg):
            calls.append(1)
            return sphere(cfg)

        res = RRSOptimizer().optimize(
            space, obj, budget=budget, rng=np.random.default_rng(0)
        )
        assert len(calls) == budget == res.n_tests
        assert res.best_value < math.inf
        assert len(res.history) == budget

    def test_more_budget_is_better(self):
        """Condition (2): larger budgets find better answers (in mean)."""
        space = sphere_space(6)
        means = []
        for budget in (20, 100, 400):
            vals = [
                RRSOptimizer()
                .optimize(space, sphere, budget, np.random.default_rng(s))
                .best_value
                for s in range(5)
            ]
            means.append(np.mean(vals))
        assert means[0] > means[1] > means[2]

    def test_escapes_local_optima(self):
        """Condition (3): on Rastrigin (many local minima), RRS keeps finding
        better basins; best-so-far must improve after exploration resumes."""
        space = ParameterSpace(
            [FloatParam(f"x{i}", -5.12, 5.12, default=4.5) for i in range(4)]
        )
        res = RRSOptimizer().optimize(
            space, rastrigin, budget=600, rng=np.random.default_rng(3)
        )
        # global optimum is 0 at x=0; a trapped hill-climber from 4.5 stays >40
        assert res.best_value < 25.0
        phases = {t.phase for t in res.history}
        assert "explore" in phases and "exploit" in phases
        # exploration happens again *after* the first exploitation: recursion
        seq = [t.phase for t in res.history]
        first_exploit = seq.index("exploit")
        assert "explore" in seq[first_exploit:]

    def test_best_so_far_monotone(self):
        space = sphere_space(5)
        res = RRSOptimizer().optimize(
            space, sphere, budget=150, rng=np.random.default_rng(1)
        )
        trace = res.best_so_far()
        assert all(a >= b for a, b in zip(trace, trace[1:]))

    def test_exploit_box_stays_in_bounds(self):
        rng = np.random.default_rng(0)
        for _ in range(100):
            center = rng.random(8)
            pt = RRSOptimizer._sample_box(center, 0.1, 8, rng)
            assert (pt >= 0).all() and (pt <= 1).all()

    def test_bad_params_rejected(self):
        with pytest.raises(ValueError):
            RRSOptimizer(r=1.5)
        with pytest.raises(ValueError):
            RRSOptimizer(c=0.0)


class TestRRSPromiseThreshold:
    """Regression (§4.3 running-quantile semantics): the promise threshold
    must be snapshotted BEFORE the exploration batch extends the evidence.

    The old order extended ``explore_values`` first and tested the batch
    minimum against a quantile the batch itself had just shifted, so a
    batch min could self-qualify for exploitation even when it beat no
    prior exploration evidence."""

    def test_batch_min_cannot_self_qualify(self, monkeypatch):
        from repro.core import rrs as rrs_mod

        space = ParameterSpace([FloatParam("x", 0.0, 4.0, default=0.0)])
        # Scripted exploration so the trace is exact: warm start at 0.0
        # (value 0), then a batch mapping to values {1, 2, 3}, then
        # high-value filler until the budget runs out.
        batches = [np.array([[0.25], [0.5], [0.75]])]

        def scripted(n, dim, rng):
            return batches.pop(0) if batches \
                else np.array([[0.95], [0.96], [0.97]])

        monkeypatch.setattr(rrs_mod, "get_sampler", lambda name: scripted)

        res = RRSOptimizer(r=0.5).optimize(
            space, lambda cfg: cfg["x"], budget=7,
            rng=np.random.default_rng(0),
            init_unit_points=np.array([[0.0]]))

        # Counterfactual: the batch-inclusive quantile would have admitted
        # the batch min (1.0 <= median([0,1,2,3]) = 1.5) ...
        assert float(np.quantile([0.0, 1.0, 2.0, 3.0], 0.5)) >= 1.0
        # ... but against the *prior* evidence (median([0.0]) = 0.0) the
        # batch min 1.0 is not promising, so exploitation never starts.
        assert res.n_tests == 7
        assert all(t.phase == "explore" for t in res.history)

    def test_prior_evidence_still_admits_genuine_improvers(self,
                                                           monkeypatch):
        """A batch min that DOES beat the prior quantile must exploit."""
        from repro.core import rrs as rrs_mod

        space = ParameterSpace([FloatParam("x", 0.0, 4.0, default=4.0)])
        batches = [np.array([[0.05], [0.9], [0.95]])]

        def scripted(n, dim, rng):
            return batches.pop(0) if batches \
                else np.array([[0.93], [0.94], [0.96]])

        monkeypatch.setattr(rrs_mod, "get_sampler", lambda name: scripted)

        res = RRSOptimizer(r=0.5).optimize(
            space, lambda cfg: cfg["x"], budget=12,
            rng=np.random.default_rng(0),
            init_unit_points=np.array([[0.5], [0.75]]))
        # prior median = 2.5; batch min 0.2 beats it => exploitation runs
        assert any(t.phase == "exploit" for t in res.history)

    def test_batched_sequential_parity_preserved(self):
        """The fix changes WHICH rounds exploit, never how rounds are
        scored: both dispatch modes still run identical trials."""
        from repro.core import MySQLSurrogate, Tuner

        sut_b, sut_s = MySQLSurrogate(), MySQLSurrogate()
        rb = Tuner(sut_b.space(), sut_b, budget=150, seed=5,
                   batch=True).run()
        rs = Tuner(sut_s.space(), sut_s, budget=150, seed=5,
                   batch=False).run()
        assert [t.config for t in rb.history] == \
               [t.config for t in rs.history]


class TestBaselines:
    @pytest.mark.parametrize("name", sorted(OPTIMIZERS))
    def test_budget_respected_and_monotone(self, name):
        space = sphere_space(4)
        calls = []

        def obj(cfg):
            calls.append(1)
            return sphere(cfg)

        res = get_optimizer(name).optimize(
            space, obj, budget=60, rng=np.random.default_rng(0)
        )
        assert len(calls) == 60
        trace = res.best_so_far()
        assert all(a >= b for a, b in zip(trace, trace[1:]))
        assert res.best_value <= trace[0]

    def test_rrs_beats_random_on_multimodal(self):
        """The structured search should win on a rugged surface (mean over seeds)."""
        space = ParameterSpace(
            [FloatParam(f"x{i}", -5.12, 5.12, default=4.5) for i in range(6)]
        )
        rrs_vals, rnd_vals = [], []
        for s in range(6):
            rrs_vals.append(
                get_optimizer("rrs")
                .optimize(space, rastrigin, 300, np.random.default_rng(s))
                .best_value
            )
            rnd_vals.append(
                get_optimizer("random")
                .optimize(space, rastrigin, 300, np.random.default_rng(s))
                .best_value
            )
        assert np.mean(rrs_vals) < np.mean(rnd_vals)
