"""Unit + property tests for ACTS parameter spaces."""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BoolParam,
    EnumParam,
    FloatParam,
    IntParam,
    ParameterSpace,
)


def make_space():
    return ParameterSpace(
        [
            BoolParam("flag", default=True),
            EnumParam("mode", ("a", "b", "c"), default="b"),
            IntParam("count", 1, 100, default=10),
            IntParam("size", 1, 2**20, default=64, log=True),
            FloatParam("ratio", 0.0, 1.0, default=0.5),
            FloatParam("rate", 1e-6, 1.0, default=1e-3, log=True),
        ]
    )


class TestRoundTrip:
    @given(st.floats(min_value=0.0, max_value=1.0, exclude_max=True))
    @settings(max_examples=100, deadline=None)
    def test_unit_roundtrip_stable(self, u):
        """from_unit → to_unit → from_unit must be a fixed point."""
        for p in make_space():
            v1 = p.from_unit(u)
            v2 = p.from_unit(p.to_unit(v1))
            assert v1 == v2, f"{p.name}: {v1} != {v2} at u={u}"

    @given(st.lists(st.floats(0.0, 1.0, exclude_max=True), min_size=6, max_size=6))
    @settings(max_examples=50, deadline=None)
    def test_vector_roundtrip(self, us):
        space = make_space()
        cfg = space.from_unit_vector(np.array(us))
        space.validate(cfg)
        cfg2 = space.from_unit_vector(space.to_unit_vector(cfg))
        assert cfg == cfg2

    def test_bounds_respected(self):
        space = make_space()
        rng = np.random.default_rng(0)
        for _ in range(200):
            cfg = space.random_config(rng)
            space.validate(cfg)
            assert 1 <= cfg["count"] <= 100
            assert 1 <= cfg["size"] <= 2**20
            assert 0.0 <= cfg["ratio"] <= 1.0
            assert 1e-6 <= cfg["rate"] <= 1.0


class TestSpace:
    def test_default(self):
        space = make_space()
        d = space.default_config()
        assert d["flag"] is True and d["mode"] == "b" and d["count"] == 10

    def test_duplicate_names_rejected(self):
        with pytest.raises(ValueError):
            ParameterSpace([BoolParam("x"), BoolParam("x")])

    def test_merge_prefix_and_subset(self):
        a = ParameterSpace([BoolParam("x"), IntParam("y", 0, 5)])
        b = ParameterSpace([BoolParam("x")])
        m = a.merge(b, prefix="jvm.")
        assert set(m.names) == {"x", "y", "jvm.x"}
        s = m.subset(["y", "jvm.x"])
        assert s.names == ["y", "jvm.x"]

    def test_freeze(self):
        space = make_space()
        view = space.freeze({"mode": "c", "flag": False})
        assert view.dim == space.dim - 2
        cfg = view.from_unit_vector(np.full(view.dim, 0.3))
        assert cfg["mode"] == "c" and cfg["flag"] is False
        assert view.default_config()["mode"] == "c"

    def test_log_cardinality(self):
        sp = ParameterSpace([BoolParam("a"), EnumParam("b", (1, 2, 3, 4, 5))])
        assert math.isclose(sp.log_cardinality(), math.log10(10))
        assert math.isinf(make_space().log_cardinality())

    def test_invalid_values_rejected(self):
        space = make_space()
        bad = space.default_config()
        bad["count"] = 101
        with pytest.raises(ValueError):
            space.validate(bad)
        missing = space.default_config()
        del missing["mode"]
        with pytest.raises(ValueError):
            space.validate(missing)

    def test_log_param_coverage(self):
        """Log-scale knobs should spread samples across decades."""
        p = IntParam("size", 1, 2**20, log=True)
        vals = [p.from_unit(u) for u in np.linspace(0, 0.999, 50)]
        decades = {int(math.log10(max(v, 1))) for v in vals}
        assert len(decades) >= 5  # covers most of the 6-decade range

    def test_enum_grid(self):
        p = EnumParam("m", ("x", "y", "z"))
        assert p.grid(30) == ["x", "y", "z"]
