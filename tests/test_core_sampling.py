"""Property tests for LHS: the paper's three sampling conditions (§4.1)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    MySQLSurrogate,
    centered_l2_discrepancy,
    lhs,
    lhs_unit,
    maximin_lhs,
    min_pairwise_distance,
    random_unit,
    stratification_counts,
)


class TestLHSProperties:
    @given(
        m=st.integers(min_value=1, max_value=64),
        dim=st.integers(min_value=1, max_value=20),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    @settings(max_examples=60, deadline=None)
    def test_stratification(self, m, dim, seed):
        """Condition (1)+(3): every interval of every knob used exactly once."""
        pts = lhs_unit(m, dim, np.random.default_rng(seed))
        assert pts.shape == (m, dim)
        assert (pts >= 0).all() and (pts < 1).all()
        assert (stratification_counts(pts) == 1).all()

    @given(
        m=st.integers(min_value=1, max_value=50),
        seed=st.integers(min_value=0, max_value=1000),
    )
    @settings(max_examples=30, deadline=None)
    def test_exact_size(self, m, seed):
        """Condition (2): |sample set| == resource limit, exactly."""
        sut = MySQLSurrogate()
        samples = lhs(sut.space(), m, np.random.default_rng(seed))
        assert len(samples) == m
        for cfg in samples:
            sut.space().validate(cfg)

    def test_maximin_is_still_lhs(self):
        pts = maximin_lhs(20, 6, np.random.default_rng(0))
        assert (stratification_counts(pts) == 1).all()

    def test_coverage_scales_with_m(self):
        """Condition (3): more budget ⇒ wider coverage (lower discrepancy)."""
        rng = np.random.default_rng(42)
        discs = []
        for m in (8, 32, 128):
            d = np.mean(
                [centered_l2_discrepancy(lhs_unit(m, 4, rng)) for _ in range(10)]
            )
            discs.append(d)
        assert discs[0] > discs[1] > discs[2]

    def test_lhs_beats_random_coverage(self):
        """LHS should be more uniform than iid-random at equal budget."""
        rng = np.random.default_rng(7)
        m, dim, reps = 32, 6, 20
        lhs_d = np.mean(
            [centered_l2_discrepancy(lhs_unit(m, dim, rng)) for _ in range(reps)]
        )
        rnd_d = np.mean(
            [centered_l2_discrepancy(random_unit(m, dim, rng)) for _ in range(reps)]
        )
        assert lhs_d < rnd_d
        lhs_md = np.mean(
            [min_pairwise_distance(lhs_unit(m, dim, rng)) for _ in range(reps)]
        )
        rnd_md = np.mean(
            [min_pairwise_distance(random_unit(m, dim, rng)) for _ in range(reps)]
        )
        assert lhs_md > rnd_md

    def test_zero_and_one_sample(self):
        assert lhs_unit(0, 3, np.random.default_rng(0)).shape == (0, 3)
        assert lhs_unit(1, 3, np.random.default_rng(0)).shape == (1, 3)
