"""Integration tests for the ACTS flexible architecture (tuner ⇄ manipulator
⇄ workload generator) and the paper's §5 case studies on surrogates."""
import json

import numpy as np
import pytest

from repro.core import (
    CallableSUT,
    ComposedSUT,
    FrontendSurrogate,
    MySQLSurrogate,
    PerfMetric,
    SparkSurrogate,
    TomcatSurrogate,
    TunableSystem,
    Tuner,
    identify_bottleneck,
)


class RecordingManipulator:
    """System manipulator that records the apply/teardown lifecycle."""

    def __init__(self, sut):
        self.sut = sut
        self.applied = []
        self.torn_down = 0

    def apply(self, config):
        self.applied.append(config)
        return config

    def teardown(self, handle):
        self.torn_down += 1


class SurrogateWorkload:
    def __init__(self, sut):
        self.sut = sut

    def run(self, handle):
        return self.sut.test(handle)


class TestFlexibleArchitecture:
    def test_manipulator_workload_decoupling(self):
        """The tuner must drive the SUT only through the two components."""
        surrogate = MySQLSurrogate()
        manip = RecordingManipulator(surrogate)
        system = TunableSystem(manip, SurrogateWorkload(surrogate), name="mysql")
        rep = Tuner(surrogate.space(), system, budget=20, seed=0).run()
        assert rep.n_tests == 20
        assert len(manip.applied) == 20  # every test restarted the SUT
        assert manip.torn_down == 20  # and tore it down afterwards
        assert rep.improvement > 1.0

    def test_resource_limit_is_hard(self):
        surrogate = MySQLSurrogate()
        calls = []

        def fn(cfg):
            calls.append(cfg)
            return surrogate.test(cfg)

        Tuner(surrogate.space(), CallableSUT(fn), budget=13, seed=0).run()
        assert len(calls) == 13

    def test_duplicate_configs_do_not_burn_budget(self):
        from repro.core import BoolParam, ParameterSpace

        # 2-knob boolean space: only 4 distinct configs exist.
        space = ParameterSpace([BoolParam("a"), BoolParam("b")])
        calls = []

        def fn(cfg):
            calls.append(tuple(sorted(cfg.items())))
            return PerfMetric(value=1.0 + cfg["a"] + 0.5 * cfg["b"])

        rep = Tuner(space, CallableSUT(fn), budget=50, seed=0).run()
        assert len(set(calls)) == len(calls)  # never re-tested a config
        assert rep.n_tests <= 4
        assert rep.best_config["a"] is True and rep.best_config["b"] is True

    def test_default_tested_first_and_never_worse(self):
        surrogate = TomcatSurrogate()
        rep = Tuner(surrogate.space(), surrogate, budget=30, seed=5).run()
        assert rep.history[0].phase == "default"
        assert rep.best_metric.value >= rep.default_metric.value  # ACTS contract

    def test_report_json_roundtrip(self):
        surrogate = SparkSurrogate()
        rep = Tuner(surrogate.space(), surrogate, budget=15, seed=0).run()
        blob = json.loads(rep.to_json())
        assert blob["n_tests"] == 15
        assert blob["improvement"] == pytest.approx(rep.improvement)
        assert len(blob["history"]) >= 15

    def test_minimization_metrics_supported(self):
        """Latency-style (lower-is-better) SUTs must tune correctly too."""
        from repro.core import FloatParam, ParameterSpace

        space = ParameterSpace([FloatParam("x", -2.0, 2.0, default=1.8)])

        def fn(cfg):
            return PerfMetric(value=cfg["x"] ** 2, higher_is_better=False)

        rep = Tuner(space, CallableSUT(fn), budget=60, seed=0).run()
        assert abs(rep.best_config["x"]) < 0.3
        assert rep.improvement > 1.0  # ratio defined in user-facing direction


class TestPaperCaseStudies:
    def test_mysql_11x(self):
        """§5.1: >11x throughput over default within a few hundred tests."""
        sut = MySQLSurrogate("uniform_read")
        rep = Tuner(sut.space(), sut, budget=200, seed=1).run()
        assert rep.default_metric.value == pytest.approx(9815, rel=0.02)
        assert rep.improvement > 10.0  # "more than 11 times" at the paper's budget
        # the surface supports 12x; make sure head-room exists
        assert rep.best_metric.value < 12.5 * rep.default_metric.value

    def test_mysql_workload_changes_dominant_knob(self):
        """§2.2/Fig 1a vs 1d: query_cache dominates reads, not writes."""
        read = MySQLSurrogate("uniform_read")
        rw = MySQLSurrogate("zipfian_rw")
        base = read.space().default_config()
        on = dict(base, query_cache_type="ON")
        gain_read = read.test(on).value / read.test(base).value
        gain_rw = rw.test(on).value / rw.test(base).value
        assert gain_read > 2.0  # dominant
        assert gain_rw < 1.1  # not dominant (invalidation overhead)

    def test_tomcat_table1_shape(self):
        """§5.2 Table 1: a few-percent txn gain, all metrics improving."""
        sut = TomcatSurrogate(fully_utilized=True)
        rep = Tuner(sut.space(), sut, budget=120, seed=3).run()
        imp = rep.improvement - 1.0
        assert 0.02 < imp < 0.08  # paper: +4.07%
        m_def, m_best = rep.default_metric.metrics, rep.best_metric.metrics
        assert m_best["hits_per_sec"] > m_def["hits_per_sec"]
        assert m_best["failed_txns"] < m_def["failed_txns"]
        assert m_best["errors"] < m_def["errors"]

    def test_jvm_knob_shifts_tomcat_optimum(self):
        """§2.2/Fig 1b vs 1e: co-deployed JVM changes where the optimum is."""
        sut = TomcatSurrogate(fully_utilized=False)
        space = sut.space()

        def best_threads(tsr):
            vals = {}
            for mt in range(25, 1000, 25):
                cfg = space.default_config()
                cfg["maxThreads"] = mt
                cfg["jvm_TargetSurvivorRatio"] = tsr
                vals[mt] = sut.test(cfg).value
            return max(vals, key=vals.get)

        assert best_threads(5) != best_threads(95)

    def test_spark_deployment_changes_surface(self):
        """§2.2/Fig 1c vs 1f: cluster mode has the cores==4 ridge."""
        alone = SparkSurrogate("standalone")
        clust = SparkSurrogate("cluster")
        base = alone.space().default_config()

        def by_cores(sut):
            return [
                sut.test(dict(base, executor_cores=c)).value for c in range(1, 9)
            ]

        va, vc = by_cores(alone), by_cores(clust)
        # standalone: saturating, no spike => consecutive ratios modest
        ratios_a = [b / a for a, b in zip(va, va[1:])]
        assert max(ratios_a) < 1.35
        # cluster: jump into cores=4, drop after
        assert vc[3] / vc[2] > 1.2 and vc[4] < vc[3]

    def test_bottleneck_identification(self):
        """§5.5: DB tunes well alone; composed stays capped => frontend."""
        db = MySQLSurrogate("zipfian_rw")
        fe = FrontendSurrogate(capacity_ceiling=11000.0)
        report = identify_bottleneck(
            {"db": db, "frontend": fe}, budget_per_system=60, seed=0
        )
        assert report.member_reports["db"].improvement > 1.5  # tunes well alone
        assert report.bottleneck == "frontend"
        assert "frontend" in report.summary()

    def test_composed_space_is_joint(self):
        db = MySQLSurrogate()
        fe = FrontendSurrogate()
        comp = ComposedSUT({"db": db, "fe": fe})
        space = comp.space()
        assert space.dim == db.space().dim + fe.space().dim
        metric = comp.test(space.default_config())
        assert metric.metrics["bottleneck_member"] in ("db", "fe")


class TestWarmStart:
    """(PR 8) ``warm_start`` seeds a run with prior winners — the online
    retuner's transfer mechanism, but a general Tuner feature with its
    own contract: seeds are tested as ordinary budgeted trials right
    after the default, infeasible seeds are skipped uncharged, and
    seeding never perturbs determinism beyond the budget it consumes."""

    def _seed(self):
        sut = MySQLSurrogate()
        # a known-good config: the winner of a generously funded run
        return sut, Tuner(sut.space(), sut, budget=40, seed=5).run()

    def test_seeds_join_history_as_warm_trials(self):
        sut, donor = self._seed()
        rep = Tuner(sut.space(), sut, budget=6, seed=0,
                    warm_start=[donor.best_config]).run()
        phases = [t.phase for t in rep.history]
        assert phases[0] == "default" and phases[1] == "warm"
        assert rep.history[1].config == donor.best_config
        assert rep.n_tests == 6  # seeds charge the same budget

    def test_best_config_contract_includes_seeds(self):
        """With no room to search, the best TESTED config is the seed
        when the seed holds up — never an untested promise."""
        sut, donor = self._seed()
        rep = Tuner(sut.space(), sut, budget=2, seed=0,
                    warm_start=[donor.best_config]).run()
        assert rep.best_metric.objective() <= \
            rep.history[0].value  # never worse than the default
        assert rep.best_config == donor.best_config or \
            rep.best_metric.objective() <= donor.best_metric.objective()

    def test_warm_run_beats_cold_at_tiny_budget(self):
        sut, donor = self._seed()
        warm = Tuner(sut.space(), sut, budget=4, seed=0,
                     warm_start=[donor.best_config]).run()
        cold = Tuner(sut.space(), sut, budget=4, seed=0).run()
        assert warm.best_metric.objective() <= cold.best_metric.objective()

    def test_seeding_is_deterministic(self):
        sut, donor = self._seed()
        runs = [Tuner(sut.space(), sut, budget=10, seed=1,
                      warm_start=[donor.best_config]).run()
                for _ in range(2)]
        assert [(tuple(sorted(t.config.items())), t.value)
                for t in runs[0].history] == \
            [(tuple(sorted(t.config.items())), t.value)
             for t in runs[1].history]

    def test_invalid_seed_raises(self):
        sut = MySQLSurrogate()
        with pytest.raises(ValueError):
            Tuner(sut.space(), sut, budget=4,
                  warm_start=[{"nonsense": 1}]).run()

    def test_infeasible_seed_skipped_uncharged(self):
        sut = MySQLSurrogate()
        seed_cfg = sut.space().default_config()
        rep = Tuner(sut.space(), sut, budget=5, seed=0,
                    warm_start=[seed_cfg],
                    feasibility=lambda c: c != seed_cfg).run()
        assert all(t.phase != "warm" for t in rep.history)
        assert rep.n_tests == 5  # the skipped seed burned nothing
