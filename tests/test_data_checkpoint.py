"""Data-pipeline determinism + checkpoint atomicity/retention/resume."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMDataset


class TestDataPipeline:
    def test_restart_safe(self):
        """batch_at(step) is a pure function — crash/restart reproduces it."""
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
        a, b = SyntheticLMDataset(cfg), SyntheticLMDataset(cfg)
        for step in (0, 7, 123):
            ba, bb = a.batch_at(step), b.batch_at(step)
            np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
            np.testing.assert_array_equal(ba["labels"], bb["labels"])

    def test_labels_are_next_tokens(self):
        cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=2)
        b = SyntheticLMDataset(cfg).batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_host_sharding_disjoint(self):
        full = SyntheticLMDataset(
            DataConfig(vocab_size=500, seq_len=8, global_batch=8))
        h0 = SyntheticLMDataset(
            DataConfig(vocab_size=500, seq_len=8, global_batch=8,
                       n_hosts=2, host_id=0))
        h1 = SyntheticLMDataset(
            DataConfig(vocab_size=500, seq_len=8, global_batch=8,
                       n_hosts=2, host_id=1))
        assert h0.host_batch == h1.host_batch == 4
        b0, b1 = h0.batch_at(3), h1.batch_at(3)
        assert not np.array_equal(b0["tokens"], b1["tokens"])

    def test_different_steps_differ(self):
        ds = SyntheticLMDataset(
            DataConfig(vocab_size=500, seq_len=16, global_batch=2))
        assert not np.array_equal(ds.batch_at(0)["tokens"],
                                  ds.batch_at(1)["tokens"])

    def test_tokens_in_vocab(self):
        ds = SyntheticLMDataset(
            DataConfig(vocab_size=100, seq_len=64, global_batch=4))
        b = ds.batch_at(5)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16)},
        "opt": {"mu": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                "step": jnp.asarray(17, jnp.int32)},
    }


class TestCheckpointManager:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree()
        mgr.save(10, tree)
        step, restored = mgr.restore(_tree(seed=1))
        assert step == 10
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            tree, restored)
        assert restored["params"]["b"].dtype == jnp.bfloat16

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree(s))
        steps = [c.step for c in mgr.all_checkpoints()]
        assert steps == [3, 4]

    def test_keep_every(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=1, keep_every=2)
        for s in (1, 2, 3):
            mgr.save(s, _tree(s))
        steps = [c.step for c in mgr.all_checkpoints()]
        assert 2 in steps and 3 in steps  # 2 kept by keep_every, 3 newest

    def test_corrupt_checkpoint_skipped(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _tree(1))
        mgr.save(2, _tree(2))
        # corrupt the newest: delete its manifest (as a torn write would)
        os.remove(os.path.join(mgr._ckpt_dir(2), "manifest.json"))
        assert mgr.latest().step == 1

    def test_tmp_junk_ignored_and_gced(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(5, _tree())
        junk = os.path.join(str(tmp_path), "step_0000000009.tmp")
        os.makedirs(junk)
        assert mgr.latest().step == 5
        CheckpointManager(str(tmp_path))  # re-open GCs tmp junk
        assert not os.path.exists(junk)

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(3, _tree())
        mgr.wait()
        assert mgr.latest().step == 3

    def test_restore_specific_step(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        for s in (1, 2, 3):
            mgr.save(s, _tree(s))
        step, restored = mgr.restore(_tree(), step=2)
        assert step == 2
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(_tree(2)["params"]["w"]))

    def test_missing_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            mgr.restore(_tree())
