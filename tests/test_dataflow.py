"""The interprocedural dataflow layer: call-graph + taint engine.

Three layers of coverage:

* pinning against the real tree — the graph must RESOLVE the repo's
  actual chains (``put_serve_config → AutotuneCache.put → _save``,
  engine nested loops → ``SlotScheduler`` decision methods, the
  ``_jit_mesh_keyed`` closure), because resolve-or-skip semantics make
  a silently-skipped edge indistinguishable from a clean one,
* property tests (hypothesis, stubbed when absent) that building a
  project and running every analysis over arbitrary syntactically-valid
  modules never raises — adversarial shapes included (self-referential
  aliases, partial chains, star-args, IfExp joins),
* accepted-pattern tests that enshrine the repo's near-misses: the
  engine's timer→metric flows stay clean while one-line mutations that
  turn them into decisions fire, and removing ``cache.py``'s flock
  dominance fires the lock rule on the real ``_save`` body.
"""
import ast
import tempfile
from pathlib import Path

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import dataflow as df
from repro.analysis import lint as L

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src" / "repro"


def _src_files():
    return [str(p) for p in sorted(SRC.rglob("*.py"))
            if "__pycache__" not in p.parts]


@pytest.fixture(scope="module")
def src_graph():
    proj = df.build_project(_src_files())
    res = df.Resolver(proj)
    return proj, res, res.call_graph()


# ---------------------------------------------------------------------------
# pinning: the graph resolves the repo's real chains
# ---------------------------------------------------------------------------
def _callers_of(graph, suffix):
    return sorted(q for q, edges in graph.items()
                  if any(e.endswith(suffix) for e in edges))


def test_put_chain_resolves(src_graph):
    """The lock rule's verification chain: every public put_* entry
    resolves into AutotuneCache.put, which resolves into _save."""
    _proj, _res, g = src_graph
    putters = _callers_of(g, ":AutotuneCache.put")
    assert "repro.autotune.api:put_serve_config" in putters
    assert "repro.autotune.api:put_train_config" in putters
    assert "repro.autotune.api:autotune_kernel" in putters
    assert _callers_of(g, ":AutotuneCache._save") == [
        "repro.autotune.cache:AutotuneCache.put"]


def test_engine_scheduler_decisions_resolve(src_graph):
    """The taint sinks are reachable in the graph: the serve loop's
    nested admission/victim helpers resolve to SlotScheduler methods
    through ctor-site inference across enclosing-function frames."""
    _proj, _res, g = src_graph
    for sink in (":SlotScheduler.pop_first_fit", ":SlotScheduler.pop",
                 ":SlotScheduler.submit", ":SlotScheduler.select_victim"):
        callers = _callers_of(g, sink)
        assert callers, f"no resolved caller for {sink}"
        assert any(q.startswith("repro.serve.engine:") for q in callers)


def test_jit_closure_sites_indexed(src_graph):
    """The PR 9 fix shape is visible to the analysis: _jit_mesh_keyed
    and its per-engine closure are both indexed functions, and the
    closure's jax.jit(keyed) call resolves keyed as a local def."""
    proj, res, _g = src_graph
    eng = proj.modules["repro.serve.engine"]
    qnames = {fi.qname for fi in eng.all_functions}
    keyed = [q for q in qnames if q.endswith(".<locals>.keyed")]
    assert any("_jit_mesh_keyed" in q for q in qnames)
    assert keyed, "per-engine closure not indexed"
    wrapper = next(fi for fi in eng.all_functions
                   if fi.name == "_jit_mesh_keyed" and fi.cls is not None)
    jit_calls = [c for c in df._own_nodes(wrapper.node, ast.Call)
                 if df._last(c.func) == "jit"]
    assert jit_calls
    tgt = res.resolve_callable(jit_calls[0].args[0], wrapper, eng)
    assert tgt is not None and tgt.fn.name == "keyed"


def test_receiver_inference_through_ifexp_and_annotation(src_graph):
    """The repo's `cache = default_cache() if cache is None else cache`
    pattern: the IfExp joins the Optional[AutotuneCache] annotation with
    default_cache()'s return annotation, and .put resolves."""
    proj, res, _g = src_graph
    api = proj.modules["repro.autotune.api"]
    fi = api.functions["put_serve_config"]
    put_calls = [c for c in df._own_nodes(fi.node, ast.Call)
                 if df._last(c.func) == "put"]
    assert len(put_calls) == 1
    tgt = res.resolve_call(put_calls[0], fi)
    assert tgt is not None
    assert tgt.fn.qname == "repro.autotune.cache:AutotuneCache.put"
    assert tgt.bound_pos == 1  # self consumed by the bound call


def test_graph_is_deterministic(src_graph):
    _proj, _res, g = src_graph
    proj2 = df.build_project(_src_files())
    g2 = df.Resolver(proj2).call_graph()
    assert g == g2
    assert list(g) == list(g2)  # iteration order is deterministic too
    for edges in g.values():
        assert edges == sorted(edges)


# ---------------------------------------------------------------------------
# property tests: resolve-or-skip never raises
# ---------------------------------------------------------------------------
_FRAGMENTS = [
    "import functools\n",
    "from repro.autotune.cache import AutotuneCache\n",
    "X = Y\nY = X\n",                                  # alias cycle
    "f = functools.partial(f, 1)\n",                   # partial self-cycle
    "def f(a, *args, **kw):\n    return f(a, *args)\n",
    "def g(x: 'Missing') -> 'AlsoMissing':\n    return x.m()\n",
    "class C:\n    def m(self):\n        return self.m()\n",
    "class D(C, Missing):\n    pass\n",
    "h = (lambda: 0) if cond else h\n",
    "def k(cache=None):\n"
    "    cache = make() if cache is None else cache\n"
    "    return cache.put(1)\n",
    "async def a():\n    await a()\n",
    "def w():\n    global G\n    G = {1}\n    for i in G:\n"
    "        yield i\n",
    "def s(xs):\n    t0 = time.time()\n"
    "    return sorted({x for x in xs}, key=lambda x: t0)\n",
    "import time\n",
    "def decide(sched):\n"
    "    if time.time() > 0:\n        return sched.pop()\n",
    "try:\n    risky()\nexcept Exception as e:\n    del e\n",
    "with open('x', 'w') as fh:\n    fh.write('')\n",
    "class E:\n    def _file_lock(self):\n        pass\n"
    "    def put(self, k):\n        self.d[k] = 1\n",
    "z: int = unknown_call()\n",
    "def p():\n    print('hi')\n",
    "@missing.decorator\ndef q(x=''):\n    return x\n",
]


@settings(max_examples=30, deadline=None)
@given(fragments=st.lists(st.sampled_from(_FRAGMENTS), min_size=0,
                          max_size=8))
def test_analyses_never_raise_on_arbitrary_modules(fragments):
    """resolve-or-skip is total: any syntactically-valid module runs
    through the project build, the call graph, and every lint pass
    without raising — opaque shapes are skipped, never guessed at."""
    source = "".join(fragments)
    ast.parse(source)  # property precondition: valid syntax
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "fixture_mod.py"
        path.write_text(source, encoding="utf-8")
        proj = df.build_project([str(path)])
        res = df.Resolver(proj)
        res.call_graph()  # must not raise
        findings = L.lint_file(path)  # full lint incl. project passes
    for f in findings:
        assert f.rule in L.RULES or f.rule == "syntax-error"


def test_builder_skips_unparseable(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def oops(:\n", encoding="utf-8")
    proj = df.build_project([str(bad)])
    assert proj.modules == {}


# ---------------------------------------------------------------------------
# accepted patterns: the repo's near-misses, enshrined
# ---------------------------------------------------------------------------
_TIMER_TEMPLATE = """\
import time


class PerfMetric:
    def __init__(self, value=0.0, wall_s=0.0):
        self.value = value
        self.wall_s = wall_s


def admission_order(policy, requests):
    return list(requests)


def run(sut, policy, requests):
    t0 = time.time()
    order = admission_order(policy, requests)
    for r in order:
        sut(r)
    return PerfMetric(value=len(order), wall_s={wall_expr})
"""


def test_timer_to_metric_stays_clean(tmp_path):
    """The engine pattern: a timer that only lands in a metric record
    is the accepted flow (ISSUE 10's precision benchmark)."""
    mod = tmp_path / "timer_ok.py"
    mod.write_text(_TIMER_TEMPLATE.format(wall_expr="time.time() - t0"),
                   encoding="utf-8")
    assert L.lint_file(mod) == []


def test_timer_to_decision_mutation_fires(tmp_path):
    """One-line mutation of the same module — the timer now perturbs
    the admission order — must fire determinism-taint."""
    src = _TIMER_TEMPLATE.format(wall_expr="0.0").replace(
        "order = admission_order(policy, requests)",
        "order = admission_order(policy, [(r, t0) for r in requests])")
    mod = tmp_path / "timer_bad.py"
    mod.write_text(src, encoding="utf-8")
    rules = [f.rule for f in L.lint_file(mod)]
    assert rules == ["determinism-taint"]


def test_engine_timer_sites_counted_and_clean():
    """engine.py really contains the ~20 timing sites the rule must
    tolerate, and lints clean standalone (not only inside the tree)."""
    engine = SRC / "serve" / "engine.py"
    n_timers = engine.read_text(encoding="utf-8").count("time.time()")
    assert n_timers >= 15
    assert [f.rule for f in L.lint_file(engine)] == []


def test_cache_without_flock_fires():
    """Deleting the flock dominance from the real cache.py must light
    up the lock rule on _save's write path — the zero-findings baseline
    is 'verified locked', not 'not checked'."""
    cache_src = (SRC / "autotune" / "cache.py").read_text(encoding="utf-8")
    assert "with self._file_lock():" in cache_src
    lines = cache_src.splitlines(keepends=True)
    out = []
    skip_indent = None
    for ln in lines:
        if "with self._file_lock():" in ln:
            skip_indent = len(ln) - len(ln.lstrip())
            continue
        if skip_indent is not None and ln.strip() \
                and not ln.startswith(" " * (skip_indent + 1)):
            skip_indent = None
        if skip_indent is not None and ln.strip():
            out.append(ln[4:] if ln.startswith("    ") else ln)
        else:
            out.append(ln)
    mutated = "".join(out)
    ast.parse(mutated)
    with tempfile.TemporaryDirectory() as td:
        p = Path(td) / "cache_unlocked.py"
        p.write_text(mutated, encoding="utf-8")
        rules = {f.rule for f in L.lint_file(p)}
    assert "cache-lock-discipline" in rules


def test_taint_summaries_cross_module(tmp_path):
    """A source in one module reaching a sink in another through an
    imported helper — the interprocedural contract, cross-module."""
    pkg = tmp_path / "pkg"
    pkg.mkdir()
    (pkg / "__init__.py").write_text("", encoding="utf-8")
    (pkg / "clock.py").write_text(
        "import time\n\n\ndef jitter():\n    return time.time()\n",
        encoding="utf-8")
    (pkg / "use.py").write_text(
        "from .clock import jitter\n\n\n"
        "def bad(space, lhs):\n    return lhs(space, 8, jitter())\n",
        encoding="utf-8")
    findings = L._lint_fileset([pkg / "__init__.py", pkg / "clock.py",
                                pkg / "use.py"])
    assert [f.rule for f in findings] == ["determinism-taint"]
    assert "jitter" in findings[0].message or "time.time" \
        in findings[0].message
