"""Determinism matrix: EVERY registered optimizer × dispatch mode × seed.

BestConfig (Zhu et al. 2017) argues a tuner is only trustworthy when its
trial sequence reproduces against the live system; this harness pins that
property for the whole optimizer registry at once:

* same seed ⇒ the identical trial sequence (configs AND values), the same
  best config and the same test count — in both dispatch modes,
* batched and sequential dispatch score the identical trial sequence
  (generalizing the RRS-only parity pin in ``test_batched_tuner.py``),
* different seeds ⇒ different trial sequences (the run is seed-driven,
  not accidentally constant),
* (PR 7) all of the above with static feasibility pruning active: the
  pruning path drops candidates deterministically — same seed ⇒ the
  identical charged-trial stream AND the identical pruned count, in
  both dispatch modes, with no budget charged to pruned configs.

The matrix iterates ``repro.core.optimizers.OPTIMIZERS`` dynamically, so a
newly registered optimizer inherits the whole determinism contract with no
test changes — if it cannot satisfy it, this file is the failing gate.
"""
import zlib

import numpy as np
import pytest

from repro.core import MySQLSurrogate, Tuner
from repro.core.optimizers import OPTIMIZERS

BUDGET = 60
SEEDS = (0, 1)


def _hash_feasible(config):
    """A deterministic, config-pure predicate rejecting ~1/4 of configs.

    crc32 (not ``hash``) so the verdict is stable across processes —
    the pruning arm's trial streams must reproduce run-to-run exactly
    like the unpruned ones.
    """
    key = repr(tuple(sorted(config.items()))).encode()
    return zlib.crc32(key) % 4 != 0


def _run(optimizer, seed, batch, feasibility=None):
    sut = MySQLSurrogate()
    tuner = Tuner(sut.space(), sut, budget=BUDGET, optimizer=optimizer,
                  seed=seed, batch=batch, feasibility=feasibility)
    return tuner.run()


def _trace(report):
    """The reproducibility-relevant content of a run."""
    return [(tuple(sorted(t.config.items())), t.value)
            for t in report.history]


def optimizer_names():
    # Import-order independence: composite registers "subspace_rr" on
    # import of repro.core, which the top-level import above forced.
    return sorted(OPTIMIZERS)


def test_registry_is_covered():
    """The matrix must actually span the registry (and the registry must
    still contain the algorithms the suite was written against)."""
    names = optimizer_names()
    assert {"rrs", "subspace_rr", "random", "lhs_only", "shc",
            "coordinate"} <= set(names)


@pytest.mark.parametrize("optimizer", optimizer_names())
class TestDeterminismMatrix:
    @pytest.mark.parametrize("seed", SEEDS)
    @pytest.mark.parametrize("batch", [True, False])
    def test_same_seed_same_trials(self, optimizer, seed, batch):
        r1 = _run(optimizer, seed, batch)
        r2 = _run(optimizer, seed, batch)
        assert _trace(r1) == _trace(r2)
        assert r1.best_config == r2.best_config
        assert r1.best_metric.value == r2.best_metric.value
        assert r1.n_tests == r2.n_tests == BUDGET

    @pytest.mark.parametrize("seed", SEEDS)
    def test_batched_sequential_parity(self, optimizer, seed):
        rb = _run(optimizer, seed, batch=True)
        rs = _run(optimizer, seed, batch=False)
        assert _trace(rb) == _trace(rs)
        assert rb.best_config == rs.best_config
        assert rb.n_tests == rs.n_tests

    def test_different_seeds_diverge(self, optimizer):
        traces = {seed: _trace(_run(optimizer, seed, batch=True))
                  for seed in SEEDS}
        assert traces[SEEDS[0]] != traces[SEEDS[1]]

    @pytest.mark.parametrize("seed", SEEDS)
    @pytest.mark.parametrize("batch", [True, False])
    def test_pruning_preserves_determinism(self, optimizer, seed, batch):
        r1 = _run(optimizer, seed, batch, feasibility=_hash_feasible)
        r2 = _run(optimizer, seed, batch, feasibility=_hash_feasible)
        assert _trace(r1) == _trace(r2)
        assert r1.n_infeasible_pruned == r2.n_infeasible_pruned
        assert r1.best_config == r2.best_config
        # pruning must actually engage, charge no budget for pruned
        # configs, and never record an infeasible trial (beyond the
        # contractually-tested default config)
        assert r1.n_infeasible_pruned > 0
        assert r1.n_tests == BUDGET
        assert all(_hash_feasible(t.config) for t in r1.history[1:])

    @pytest.mark.parametrize("seed", SEEDS)
    def test_pruning_batched_sequential_parity(self, optimizer, seed):
        rb = _run(optimizer, seed, batch=True,
                  feasibility=_hash_feasible)
        rs = _run(optimizer, seed, batch=False,
                  feasibility=_hash_feasible)
        assert _trace(rb) == _trace(rs)
        assert rb.n_infeasible_pruned == rs.n_infeasible_pruned
        assert rb.best_config == rs.best_config
        assert rb.n_tests == rs.n_tests


def _retune_trace(optimizer, seed, batch):
    """(PR 8) Drive the serve loop's online shift detector over a fixed
    synthetic workload trace: steady long prompts, then a shift to short
    shared-prefix bursts.  Returns [(trigger step, winner items)] — the
    reproducibility-relevant content of the retuning decisions."""
    from repro.serve.space import CotuneParams, serve_knob_space
    from repro.serve.workload import OnlineRetuner, WorkloadWindow

    rt = OnlineRetuner(serve_knob_space(48, max_slots=8),
                       CotuneParams(max_seq=48, prompt_len=24, gen_len=12),
                       budget=8, threshold=0.25, min_requests=4,
                       cooldown=12, check_every=2, optimizer=optimizer,
                       seed=seed, batch=batch)
    rng = np.random.default_rng(7)  # trace seed: fixed, not the tuner's
    window = WorkloadWindow(capacity=8)
    shared = rng.integers(1, 500, size=20).tolist()
    out = []
    for step in range(48):
        if step % 4 == 0:
            if step < 20:
                window.record_request(
                    step, rng.integers(1, 500, size=24).tolist(), 12)
            else:
                for _ in range(3):
                    window.record_request(
                        step,
                        shared + rng.integers(1, 500, size=2).tolist(), 3)
        window.record_depth(2 if step < 20 else 8)
        hit = rt.maybe_retune(window, step)
        if hit is not None:
            out.append((hit["step"],
                        tuple(sorted(hit["config"].items()))))
    return out


@pytest.mark.parametrize("optimizer", optimizer_names())
class TestRetuneDeterminism:
    """The online retuning loop inherits the registry-wide determinism
    contract: the shift DETECTION step is a function of the trace alone
    (identical across optimizers, seeds and dispatch modes), and the
    retuned winner reproduces per (optimizer, seed) in both modes."""

    @pytest.mark.parametrize("seed", SEEDS)
    @pytest.mark.parametrize("batch", [True, False])
    def test_same_trace_same_retunes(self, optimizer, seed, batch):
        t1 = _retune_trace(optimizer, seed, batch)
        t2 = _retune_trace(optimizer, seed, batch)
        assert t1 == t2
        assert len(t1) >= 1  # the shift must actually be detected

    @pytest.mark.parametrize("seed", SEEDS)
    def test_retune_batched_sequential_parity(self, optimizer, seed):
        assert _retune_trace(optimizer, seed, batch=True) == \
            _retune_trace(optimizer, seed, batch=False)

    def test_trigger_steps_are_tuner_independent(self, optimizer):
        """WHEN to retune depends only on the observed workload — the
        optimizer and its seed may change the winner, never the step."""
        steps = {(seed, batch): [s for s, _ in
                                 _retune_trace(optimizer, seed, batch)]
                 for seed in SEEDS for batch in (True, False)}
        baseline = steps[(SEEDS[0], True)]
        assert all(v == baseline for v in steps.values())
        # and against the reference optimizer, too
        ref = [s for s, _ in _retune_trace("rrs", SEEDS[0], True)]
        assert baseline == ref
