"""Static feasibility: the predicate layer and zero-budget pruning.

Pins the PR-7 contracts:

* feasible ⇔ finite cost — for EVERY ``KERNELS`` entry, the feasibility
  model and the roofline cost model agree on hard infeasibility over
  random configs (they share one ``vmem_footprint``, so disagreement
  means the factoring regressed),
* pruning charges no budget — a tune over a space with statically
  infeasible configs spends its full budget on feasible configs only,
  counts the pruned ones, and stays exactly seed-deterministic,
* the serve deployability floor — ``serve_feasibility`` rejects
  precisely the configs ``apply_serve_knobs`` would mutate, so fresh
  tuning cannot produce a floor raise (the warn-once path stays
  reachable only for pre-PR7 cached winners),
* composite routing — ``CompositeFeasibility`` evaluates member models
  on their prefixed subconfigs.
"""
import math
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis.feasibility import (CompositeFeasibility,
                                        FeasibilityModel, Predicate,
                                        kernel_feasibility,
                                        serve_feasibility)
from repro.autotune.space import KERNELS, VMEM_BYTES, KernelSpace
from repro.autotune.sut import KernelSUT
from repro.core.tuner import Tuner

# Shapes chosen so the VMEM budget genuinely splits each kernel's space:
# large model dims make the biggest tiles infeasible while the small ones
# stay finite — the iff below is then exercised on both sides.
DIMS = {
    "flash_attention": {"B": 2, "S": 8192, "SK": 8192, "H": 8, "KV": 8,
                        "D": 1024},
    "decode_attention": {"B": 8, "S": 8192, "H": 8, "KV": 1, "D": 1024},
    "paged_attention": {"B": 8, "S": 8192, "H": 8, "KV": 1, "D": 2048},
    "gla": {"B": 2, "S": 8192, "H": 4, "DK": 1024, "DV": 1024},
    "rmsnorm": {"ROWS": 8192, "D": 6144},
}

RMSNORM_DIMS = {"ROWS": 8192, "D": 6144}  # block_rows 512+ blows VMEM


def _cfg(kernel, seed):
    space = KernelSpace(kernel).space()
    rng = np.random.default_rng(seed)
    return space.from_unit_vector(rng.random(space.dim))


@pytest.mark.parametrize("kernel", sorted(KERNELS))
class TestFeasibleIffFiniteCost:
    @settings(max_examples=40)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_iff(self, kernel, seed):
        dims = KernelSpace(kernel).validate_dims(DIMS[kernel])
        model = kernel_feasibility(kernel, dims, "float32")
        cfg = _cfg(kernel, seed)
        cost = float(KERNELS[kernel].model_cost(cfg, dims, "float32"))
        assert model(cfg) == (cost < math.inf), (
            f"feasibility/cost disagree on {kernel} cfg={cfg}: "
            f"feasible={model(cfg)} cost={cost}")

    def test_footprint_is_the_only_inf_source(self, kernel):
        """cost == inf exactly when the shared footprint exceeds VMEM."""
        dims = KernelSpace(kernel).validate_dims(DIMS[kernel])
        kdef = KERNELS[kernel]
        for seed in range(64):
            cfg = _cfg(kernel, seed)
            over = kdef.vmem_footprint(cfg, dims, "float32") > VMEM_BYTES
            cost = float(kdef.model_cost(cfg, dims, "float32"))
            assert (cost == math.inf) == over


def test_split_is_real():
    """The rmsnorm probe shape has both feasible and infeasible configs
    (otherwise the pruning tests below exercise nothing)."""
    model = kernel_feasibility("rmsnorm", RMSNORM_DIMS, "float32")
    verdicts = {model({"block_rows": br, "dim_semantics": None})
                for br in (128, 256, 512, 1024)}
    assert verdicts == {True, False}


def test_alignment_is_warn_only():
    """A misaligned-but-fitting tile is feasible (finite cost penalty),
    but ``check`` surfaces the warning."""
    dims = {"ROWS": 100, "D": 512}  # 100 % 8 != 0: sublane-misaligned
    model = kernel_feasibility("rmsnorm", dims, "float32")
    cfg = {"block_rows": 128, "dim_semantics": None}
    assert model(cfg)
    sevs = {v.severity for v in model.check(cfg)}
    assert sevs == {"warn"}
    assert "sublane" in model.explain(cfg)


# ---------------------------------------------------------------------------
# zero-budget pruning through the Tuner
# ---------------------------------------------------------------------------
def _tune(budget=24, seed=0, **kw):
    sut = KernelSUT("rmsnorm", RMSNORM_DIMS, mode="model")
    return Tuner(sut.space(), sut, budget=budget, optimizer="rrs",
                 seed=seed, **kw).run()


def _trace(report):
    return [(tuple(sorted(t.config.items())), t.value)
            for t in report.history]


class TestPruning:
    def test_no_budget_charged_to_infeasible(self):
        rep = _tune()
        model = kernel_feasibility("rmsnorm", RMSNORM_DIMS, "float32")
        space = KernelSpace("rmsnorm").space()
        n_feasible = sum(
            model({"block_rows": br, "dim_semantics": ds})
            for br in space["block_rows"].grid(10**6)
            for ds in space["dim_semantics"].grid(10**6))
        assert rep.n_infeasible_pruned > 0
        # pruning + config dedup explore exactly the feasible region:
        # the budget of 24 cannot be filled by 16 - 4 distinct configs
        assert 0 < n_feasible < 24
        assert rep.n_tests == n_feasible
        # the default config is contractually tested even if infeasible;
        # every *searched* trial must be feasible and finitely scored
        for t in rep.history[1:]:
            assert model(t.config), t.config
            assert math.isfinite(t.value)

    def test_pruning_is_seed_deterministic(self):
        for seed in (0, 1):
            r1, r2 = _tune(seed=seed), _tune(seed=seed)
            assert _trace(r1) == _trace(r2)
            assert r1.n_infeasible_pruned == r2.n_infeasible_pruned
            assert r1.best_config == r2.best_config

    def test_feasibility_false_disables(self):
        rep = _tune(feasibility=False)
        assert rep.n_infeasible_pruned == 0
        # without pruning the searcher pays for inf configs
        assert any(not math.isfinite(t.value) for t in rep.history)

    def test_pruned_run_never_worse(self):
        on, off = _tune(), _tune(feasibility=False)
        assert on.best_metric.value <= off.best_metric.value

    def test_non_callable_feasibility_rejected(self):
        sut = KernelSUT("rmsnorm", RMSNORM_DIMS, mode="model")
        with pytest.raises(TypeError):
            Tuner(sut.space(), sut, budget=4, feasibility=42)

    def test_empty_feasible_region_terminates(self):
        sut = KernelSUT("rmsnorm", RMSNORM_DIMS, mode="model")
        tuner = Tuner(sut.space(), sut, budget=8,
                      feasibility=lambda cfg: False)
        with warnings.catch_warnings():
            # every round scores all-inf: numpy's percentile math emits
            # a benign invalid-subtract warning in this degenerate case
            warnings.simplefilter("ignore", RuntimeWarning)
            rep = tuner.run()  # MAX_CONSECUTIVE_PRUNED ends the search
        # only the unconditional default test is charged
        assert rep.n_tests == 1
        assert rep.n_infeasible_pruned > 0


# ---------------------------------------------------------------------------
# serve deployability floor
# ---------------------------------------------------------------------------
class TestServeFloor:
    def test_paged_floor_boundary(self):
        from repro.serve.paging import min_pages_for

        floor = min_pages_for(2048, 1)
        model = serve_feasibility(2048)
        base = {"max_batch": 8}
        assert not model({**base, "kv_cache_pages": floor - 1})
        assert model({**base, "kv_cache_pages": floor})

    def test_dense_floor_scales_with_slots(self):
        from repro.serve.paging import PAGE_TOKENS

        model = serve_feasibility(2048, kv_layout="dense")
        need = 8 * 2048 // PAGE_TOKENS
        assert not model({"max_batch": 8, "kv_cache_pages": need - 1})
        assert model({"max_batch": 8, "kv_cache_pages": need})
        assert model({"max_batch": 1, "kv_cache_pages": 2048 // PAGE_TOKENS})

    def test_feasible_configs_deploy_unmutated(self):
        """The predicate encodes apply_serve_knobs' floor exactly: a
        feasible config round-trips with its tuned page count intact."""
        import repro.serve.space as sspace
        from repro.serve.engine import ServeConfig

        base = ServeConfig(runtime="continuous", kv_layout="paged")
        model = serve_feasibility(base.max_seq, runtime=base.runtime,
                                  kv_layout=base.kv_layout,
                                  kv_page_block=base.kv_page_block)
        space = sspace.serve_knob_space(base.max_seq)
        rng = np.random.default_rng(7)
        checked = 0
        for _ in range(200):
            cfg = space.from_unit_vector(rng.random(space.dim))
            if not model(cfg):
                continue
            before = sspace.kv_floor_raise_count()
            deployed = sspace.apply_serve_knobs(cfg, base=base)
            assert sspace.kv_floor_raise_count() == before
            assert deployed.kv_cache_pages == int(cfg["kv_cache_pages"])
            checked += 1
        assert checked > 0

    def test_floor_raise_warns_once_and_counts(self):
        import repro.serve.space as sspace
        from repro.serve.engine import ServeConfig

        base = ServeConfig(runtime="continuous", kv_layout="paged")
        below = {"max_batch": 4, "prefill_chunk": 128,
                 "kv_cache_pages": 1, "schedule": "fifo",
                 "page_policy": "reserve", "share_prefix": 0,
                 "draft_len": 0}
        sspace._floor_raise_warned = False  # re-arm the once-latch
        before = sspace.kv_floor_raise_count()
        with pytest.warns(RuntimeWarning, match="deployable floor"):
            sspace.apply_serve_knobs(below, base=base)
        assert sspace.kv_floor_raise_count() == before + 1
        # second raise counts but does not warn again
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            sspace.apply_serve_knobs(below, base=base)
        assert sspace.kv_floor_raise_count() == before + 2

    def test_fresh_surrogate_tuning_cannot_raise(self):
        """A winner tuned under the auto-detected serve feasibility is
        deployable as-is."""
        import repro.serve.space as sspace
        from repro.serve.engine import ServeConfig

        sut = sspace.ServeSurrogate()
        rep = Tuner(sut.space(), sut, budget=32, optimizer="rrs",
                    seed=3).run()
        assert sut.feasibility_model(rep.best_config)
        base = ServeConfig(runtime="continuous", kv_layout="paged")
        before = sspace.kv_floor_raise_count()
        sspace.apply_serve_knobs(rep.best_config, base=base)
        assert sspace.kv_floor_raise_count() == before


class TestServeMeshPredicates:
    """The PR-9 sharding predicates: a tuned mesh the engine would
    refuse to build (or silently replicate) is infeasible up front."""

    BASE = {"max_batch": 8, "kv_cache_pages": 512}

    def test_mesh_must_fit_device_count(self):
        model = serve_feasibility(2048, n_devices=8)
        assert model({**self.BASE, "mesh_devices": 8,
                      "tp_vs_replicas": "replicas"})
        assert not model({**self.BASE, "mesh_devices": 16,
                          "tp_vs_replicas": "tp"})
        # 8 % 3 != 0: ServeEngine raises on this mesh
        assert not model({**self.BASE, "mesh_devices": 3,
                          "tp_vs_replicas": "tp"})

    def test_tp_needs_heads_to_divide(self):
        model = serve_feasibility(2048, n_devices=8, n_heads=12,
                                  n_kv_heads=4)
        # 12 heads % 8 != 0 under TP -> spec_for_shape would replicate
        # attention: the deployed engine is not the one the tuner scored
        assert not model({**self.BASE, "mesh_devices": 8,
                          "tp_vs_replicas": "tp"})
        assert model({**self.BASE, "mesh_devices": 4,
                      "tp_vs_replicas": "tp"})
        # replicas never split heads: any dividing device count is fine
        assert model({**self.BASE, "mesh_devices": 8,
                      "tp_vs_replicas": "replicas"})

    def test_kv_heads_violation_is_warn_only(self):
        model = serve_feasibility(2048, n_devices=8, n_heads=8,
                                  n_kv_heads=4)
        cfg = {**self.BASE, "mesh_devices": 8, "tp_vs_replicas": "tp"}
        assert model(cfg)  # feasible: the pool replicates, decode works
        assert any(v.predicate == "kv_heads_shardable"
                   and v.severity == "warn"
                   for v in model.check(cfg))

    def test_unknown_topology_skips(self):
        """No n_devices/n_heads kwargs (the historical callers): mesh
        knobs pass — unknown is not violated."""
        model = serve_feasibility(2048)
        assert model({**self.BASE, "mesh_devices": 16,
                      "tp_vs_replicas": "tp"})

    def test_legacy_configs_unaffected(self):
        model = serve_feasibility(2048, n_devices=8, n_heads=12,
                                  n_kv_heads=4)
        assert model(self.BASE)  # no mesh knobs at all

    def test_fresh_sharded_tuning_is_deployable(self):
        """Every winner of a max_devices-widened surrogate tune builds:
        the acceptance bar 'fresh tunes never produce an undeployable
        mesh'."""
        import repro.serve.space as sspace

        sut = sspace.ServeSurrogate(max_devices=8)
        for seed in range(3):
            rep = Tuner(sut.space(), sut, budget=24, optimizer="rrs",
                        seed=seed).run()
            best = rep.best_config
            assert sut.feasibility_model(best)
            n_dev = int(best.get("mesh_devices", 1))
            assert 8 % n_dev == 0
            if n_dev > 1 and best.get("tp_vs_replicas") == "tp":
                assert sut.params.heads % n_dev == 0


# ---------------------------------------------------------------------------
# composition
# ---------------------------------------------------------------------------
class TestComposite:
    def test_prefix_routing(self):
        kernel = kernel_feasibility("rmsnorm", RMSNORM_DIMS, "float32")
        serve = serve_feasibility(2048)
        joint = CompositeFeasibility({"kernel": kernel, "serve": serve})
        good = {"kernel.block_rows": 256, "kernel.dim_semantics": None,
                "serve.max_batch": 8, "serve.kv_cache_pages": 512}
        assert joint(good)
        assert not joint({**good, "kernel.block_rows": 1024})
        assert not joint({**good, "serve.kv_cache_pages": 1})
        names = {v.predicate for v in joint.check(
            {**good, "kernel.block_rows": 1024,
             "serve.kv_cache_pages": 1})}
        assert {"kernel.vmem_fits", "serve.kv_pages_floor"} <= names

    def test_cotune_sut_composes_serve_floor(self):
        from repro.serve.space import make_cotune_sut

        sut = make_cotune_sut()
        model = sut.feasibility_model
        assert model is not None
        cfg = sut.space().default_config()
        assert model(cfg)
        bad = dict(cfg)
        bad["serve.kv_cache_pages"] = 1
        assert not model(bad)

    def test_predicate_severity_validated(self):
        with pytest.raises(ValueError):
            Predicate("p", lambda c: None, severity="fatal")
        # a valid model built from valid predicates round-trips
        model = FeasibilityModel("m", predicates=[
            Predicate("p", lambda c: None)])
        assert model({})
