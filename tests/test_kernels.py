"""Per-kernel validation: shape/dtype sweeps + hypothesis, allclose against
the pure-jnp oracles in ``repro.kernels.ref`` (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gla import gla_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ref import attention_ref, gla_ref, rmsnorm_ref

TOL = {
    jnp.float32: dict(rtol=2e-5, atol=2e-5),
    jnp.bfloat16: dict(rtol=2e-2, atol=2e-2),
}


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "B,S,H,KV,D,bq,bk",
        [
            (1, 16, 1, 1, 8, 16, 16),     # minimal
            (2, 64, 4, 2, 16, 32, 32),    # GQA
            (1, 96, 8, 1, 32, 32, 32),    # MQA, non-square blocks
            (2, 100, 4, 4, 16, 32, 16),   # ragged seq vs blocks (padding)
            (1, 128, 2, 2, 64, 64, 128),  # bq < bk
        ],
    )
    def test_against_ref(self, dtype, B, S, H, KV, D, bq, bk):
        rng = np.random.default_rng(hash((B, S, H, KV, D)) % 2**31)
        q = _rand(rng, (B, S, H, D), dtype)
        k = _rand(rng, (B, S, KV, D), dtype)
        v = _rand(rng, (B, S, KV, D), dtype)
        out = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                     block_kv=bk, interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype])

    @pytest.mark.parametrize("window", [8, 24, 64])
    def test_sliding_window(self, window):
        rng = np.random.default_rng(window)
        q = _rand(rng, (2, 72, 4, 16), jnp.float32)
        k = _rand(rng, (2, 72, 2, 16), jnp.float32)
        v = _rand(rng, (2, 72, 2, 16), jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                     block_q=16, block_kv=16, interpret=True)
        ref = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_non_causal(self):
        rng = np.random.default_rng(7)
        q = _rand(rng, (1, 48, 2, 16), jnp.float32)
        k = _rand(rng, (1, 48, 2, 16), jnp.float32)
        v = _rand(rng, (1, 48, 2, 16), jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=False, block_q=16,
                                     block_kv=16, interpret=True)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    @given(
        S=st.integers(4, 80),
        H=st.sampled_from([1, 2, 4]),
        G=st.sampled_from([1, 2]),
        D=st.sampled_from([8, 16]),
        bq=st.sampled_from([8, 16, 32]),
        bk=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_random_shapes(self, S, H, G, D, bq, bk, seed):
        KV = max(1, H // G)
        H = KV * G
        rng = np.random.default_rng(seed)
        q = _rand(rng, (1, S, H, D), jnp.float32)
        k = _rand(rng, (1, S, KV, D), jnp.float32)
        v = _rand(rng, (1, S, KV, D), jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                     block_kv=bk, interpret=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


class TestRMSNorm:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("shape,block", [
        ((4, 32), 4), ((3, 7, 64), 16), ((1, 128), 256), ((5, 100), 32),
    ])
    def test_against_ref(self, dtype, shape, block):
        rng = np.random.default_rng(hash(shape) % 2**31)
        x = _rand(rng, shape, dtype)
        s = _rand(rng, (shape[-1],), jnp.float32)
        out = rmsnorm_pallas(x, s, block_rows=block, interpret=True)
        ref = rmsnorm_ref(x, s)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype])

    @given(rows=st.integers(1, 50), d=st.sampled_from([8, 32, 128]),
           seed=st.integers(0, 50))
    @settings(max_examples=20, deadline=None)
    def test_property(self, rows, d, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (rows, d), jnp.float32)
        s = _rand(rng, (d,), jnp.float32)
        out = rmsnorm_pallas(x, s, block_rows=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(rmsnorm_ref(x, s)),
                                   rtol=2e-5, atol=2e-5)


class TestGLA:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,H,dk,dv,chunk", [
        (1, 16, 1, 4, 4, 8),
        (2, 64, 3, 8, 16, 16),
        (1, 70, 2, 16, 8, 32),   # ragged
        (2, 128, 4, 32, 32, 64),
    ])
    def test_against_ref(self, dtype, B, S, H, dk, dv, chunk):
        rng = np.random.default_rng(hash((B, S, H, dk, dv)) % 2**31)
        q = _rand(rng, (B, S, H, dk), dtype)
        k = _rand(rng, (B, S, H, dk), dtype)
        v = _rand(rng, (B, S, H, dv), dtype)
        g = jnp.asarray(-np.abs(rng.normal(size=(B, S, H)) * 0.3), jnp.float32)
        y, state = gla_pallas(q, k, v, g, chunk=chunk, interpret=True)
        yr, sr = gla_ref(q, k, v, g)
        tol = TOL[dtype]
        # chunked vs O(S^2) reference accumulate in different orders; the
        # largest case needs the same slack the state comparison gets
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32),
                                   rtol=max(tol["rtol"], 5e-5),
                                   atol=max(tol["atol"], 5e-5))
        np.testing.assert_allclose(np.asarray(state), np.asarray(sr),
                                   rtol=max(tol["rtol"], 1e-4),
                                   atol=max(tol["atol"], 1e-4))

    def test_matches_model_core(self):
        """Kernel ≡ the chunked-jnp core the models actually run."""
        from repro.models.gla import chunked_gla

        rng = np.random.default_rng(0)
        q = _rand(rng, (2, 48, 2, 8), jnp.float32)
        k = _rand(rng, (2, 48, 2, 8), jnp.float32)
        v = _rand(rng, (2, 48, 2, 8), jnp.float32)
        g = jnp.asarray(-np.abs(rng.normal(size=(2, 48, 2)) * 0.2), jnp.float32)
        y1, s1 = gla_pallas(q, k, v, g, chunk=16, interpret=True)
        y2, s2 = chunked_gla(q, k, v, g, chunk=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=2e-5, atol=2e-5)

    @given(S=st.integers(4, 60), chunk=st.sampled_from([4, 8, 16, 32]),
           seed=st.integers(0, 50))
    @settings(max_examples=20, deadline=None)
    def test_property_chunk_invariance(self, S, chunk, seed):
        """Output must not depend on the chunk size (tiling invariance)."""
        rng = np.random.default_rng(seed)
        q = _rand(rng, (1, S, 1, 8), jnp.float32)
        k = _rand(rng, (1, S, 1, 8), jnp.float32)
        v = _rand(rng, (1, S, 1, 8), jnp.float32)
        g = jnp.asarray(-np.abs(rng.normal(size=(1, S, 1)) * 0.5), jnp.float32)
        y, st_ = gla_pallas(q, k, v, g, chunk=chunk, interpret=True)
        yr, sr = gla_ref(q, k, v, g)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=5e-5, atol=5e-5)
        np.testing.assert_allclose(np.asarray(st_), np.asarray(sr),
                                   rtol=1e-4, atol=1e-4)


class TestOpsWrappers:
    def test_ops_jit(self):
        from repro.kernels import ops

        rng = np.random.default_rng(0)
        q = _rand(rng, (1, 32, 2, 16), jnp.float32)
        k = _rand(rng, (1, 32, 2, 16), jnp.float32)
        v = _rand(rng, (1, 32, 2, 16), jnp.float32)
        out = ops.flash_attention(q, k, v, block_q=16, block_kv=16)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        x = _rand(rng, (8, 32), jnp.float32)
        s = _rand(rng, (32,), jnp.float32)
        np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, s)),
                                   np.asarray(rmsnorm_ref(x, s)),
                                   rtol=2e-5, atol=2e-5)


class TestPagedDecode:
    """Paged decode attention: the Pallas kernel gathers K/V through the
    page table (scalar-prefetch index maps) and must match both its jnp
    gather reference and the dense attention oracle on the logically
    ordered cache."""

    def _pool(self, rng, B, maxg, T, KV, D, dtype, extra=3):
        G = B * maxg + extra  # a few unused groups (incl. scratch-like 0)
        kp = _rand(rng, (G, T, KV, D), dtype)
        vp = _rand(rng, (G, T, KV, D), dtype)
        # random non-identity table over groups 1..G-1, unique per entry
        perm = 1 + rng.permutation(G - 1)[:B * maxg]
        pt = jnp.asarray(perm.reshape(B, maxg), jnp.int32)
        return kp, vp, pt

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,KV,D,T,maxg", [
        (1, 2, 2, 8, 16, 2),
        (2, 8, 2, 16, 32, 3),   # GQA, multi-page
        (3, 4, 1, 32, 16, 4),   # MQA
    ])
    def test_against_refs(self, dtype, B, H, KV, D, T, maxg):
        from repro.kernels.paged_attention import (paged_attention_ref,
                                                   paged_flash_decode_pallas)

        rng = np.random.default_rng(hash((B, H, KV, D, T)) % 2**31)
        q = _rand(rng, (B, H, D), dtype)
        kp, vp, pt = self._pool(rng, B, maxg, T, KV, D, dtype)
        lengths = jnp.asarray(
            rng.integers(1, maxg * T, size=B), jnp.int32)
        out = paged_flash_decode_pallas(q, kp, vp, pt, lengths,
                                        interpret=True)
        ref = paged_attention_ref(q, kp, vp, pt, lengths)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype])
        # gather-to-dense oracle per sequence
        kd = kp[pt].reshape(B, maxg * T, KV, D)
        vd = vp[pt].reshape(B, maxg * T, KV, D)
        for b in range(B):
            L = int(lengths[b])
            dense = attention_ref(q[b:b + 1, None], kd[b:b + 1, :L],
                                  vd[b:b + 1, :L], causal=False)[:, 0]
            np.testing.assert_allclose(
                np.asarray(out[b:b + 1], np.float32),
                np.asarray(dense, np.float32), **TOL[dtype])

    def test_page_table_permutation_invariance(self):
        """Physically scattering the same logical cache across different
        groups must not change the output at all."""
        from repro.kernels.paged_attention import paged_flash_decode_pallas

        rng = np.random.default_rng(11)
        B, H, KV, D, T, maxg = 2, 4, 2, 16, 16, 3
        q = _rand(rng, (B, H, D), jnp.float32)
        logical_k = _rand(rng, (B, maxg * T, KV, D), jnp.float32)
        logical_v = _rand(rng, (B, maxg * T, KV, D), jnp.float32)
        lengths = jnp.asarray([40, 17], jnp.int32)
        outs = []
        for seed in (0, 1):
            prm = 1 + np.random.default_rng(seed).permutation(B * maxg)
            G = B * maxg + 2
            kp = np.zeros((G, T, KV, D), np.float32)
            vp = np.zeros((G, T, KV, D), np.float32)
            pt = prm.reshape(B, maxg)
            for b in range(B):
                for g in range(maxg):
                    kp[pt[b, g]] = np.asarray(logical_k)[b, g * T:(g + 1) * T]
                    vp[pt[b, g]] = np.asarray(logical_v)[b, g * T:(g + 1) * T]
            outs.append(np.asarray(paged_flash_decode_pallas(
                q, jnp.asarray(kp), jnp.asarray(vp),
                jnp.asarray(pt, jnp.int32), lengths, interpret=True)))
        np.testing.assert_array_equal(outs[0], outs[1])

    @given(maxg=st.integers(1, 4), T=st.sampled_from([16, 32]),
           kv_len=st.integers(1, 120), seed=st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_property_dynamic_length(self, maxg, T, kv_len, seed):
        from repro.kernels.paged_attention import (paged_attention_ref,
                                                   paged_flash_decode_pallas)

        kv_len = min(kv_len, maxg * T)
        rng = np.random.default_rng(seed)
        q = _rand(rng, (1, 4, 8), jnp.float32)
        kp, vp, pt = self._pool(rng, 1, maxg, T, 2, 8, jnp.float32)
        lengths = jnp.asarray([kv_len], jnp.int32)
        out = paged_flash_decode_pallas(q, kp, vp, pt, lengths,
                                        interpret=True)
        ref = paged_attention_ref(q, kp, vp, pt, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)

    def test_ops_wrapper_resolves_launch_knobs(self):
        from repro.kernels import ops

        rng = np.random.default_rng(3)
        q = _rand(rng, (2, 4, 16), jnp.float32)
        kp, vp, pt = self._pool(rng, 2, 2, 16, 2, 16, jnp.float32)
        lengths = jnp.asarray([20, 7], jnp.int32)
        out = ops.paged_flash_decode(q, kp, vp, pt, lengths)
        from repro.kernels.paged_attention import paged_attention_ref

        np.testing.assert_allclose(
            np.asarray(out), np.asarray(paged_attention_ref(
                q, kp, vp, pt, lengths)), rtol=2e-5, atol=2e-5)


class TestFlashDecode:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,H,KV,D,bkv", [
        (1, 32, 2, 2, 8, 16),
        (2, 96, 8, 2, 16, 32),
        (1, 100, 4, 1, 32, 32),   # MQA + ragged cache
    ])
    def test_against_ref(self, dtype, B, S, H, KV, D, bkv):
        from repro.kernels.decode_attention import flash_decode_pallas

        rng = np.random.default_rng(hash((B, S, H, KV, D)) % 2**31)
        q = _rand(rng, (B, H, D), dtype)
        k = _rand(rng, (B, S, KV, D), dtype)
        v = _rand(rng, (B, S, KV, D), dtype)
        for kv_len in (1, S // 3, S):
            out = flash_decode_pallas(q, k, v, kv_len, block_kv=bkv,
                                      interpret=True)
            ref = attention_ref(q[:, None], k[:, :kv_len], v[:, :kv_len],
                                causal=False)[:, 0]
            np.testing.assert_allclose(
                np.asarray(out, np.float32), np.asarray(ref, np.float32),
                **TOL[dtype])

    @given(S=st.integers(8, 80), kv_len=st.integers(1, 80),
           bkv=st.sampled_from([8, 16, 32]), seed=st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_property_dynamic_length(self, S, kv_len, bkv, seed):
        from repro.kernels.decode_attention import flash_decode_pallas

        kv_len = min(kv_len, S)
        rng = np.random.default_rng(seed)
        q = _rand(rng, (1, 4, 8), jnp.float32)
        k = _rand(rng, (1, S, 2, 8), jnp.float32)
        v = _rand(rng, (1, S, 2, 8), jnp.float32)
        out = flash_decode_pallas(q, k, v, kv_len, block_kv=bkv,
                                  interpret=True)
        ref = attention_ref(q[:, None], k[:, :kv_len], v[:, :kv_len],
                            causal=False)[:, 0]
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)
