"""The jit/Pallas/allocator lint: planted-hazard fixtures + repo gate.

Three layers of pinning:

* the fixture files in ``tests/fixtures/lint/`` plant one violation per
  rule family (plus deliberate look-alikes that must NOT fire: an unwind
  path that releases, a ``list.extend`` inside a try) — each expected
  finding is asserted by rule and file,
* the pragma file suppresses every planted hazard and must come back
  clean,
* ``src/repro`` itself must lint clean — this is the same gate
  ``scripts/ci.sh`` runs, kept here so a plain pytest run catches a
  violation before CI does — while the kernels under ``src/repro``
  prove the checks resolve real call sites rather than skipping them
  (``_probe`` counts resolved jit/pallas sites).
"""
import ast
import json
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import lint as L

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "lint"
SRC = REPO / "src" / "repro"


def _rules(findings):
    return sorted(f.rule for f in findings)


def test_fixture_retrace_findings():
    got = _rules(L.lint_file(FIXTURES / "bad_retrace.py"))
    assert got == ["jit-static-missing", "jit-static-mutable-default",
                   "jit-traced-str-default"]


def test_fixture_pallas_findings():
    findings = L.lint_file(FIXTURES / "bad_pallas.py")
    assert _rules(findings) == [
        "pallas-index-map-arity", "pallas-kernel-arity",
        "pallas-operand-arity", "pallas-vmem-scratch"]
    sev = {f.rule: f.severity for f in findings}
    assert sev["pallas-vmem-scratch"] == "warning"
    assert all(s == "error" for r, s in sev.items()
               if r != "pallas-vmem-scratch")


def test_fixture_alloc_findings():
    findings = L.lint_file(FIXTURES / "bad_alloc.py")
    assert _rules(findings) == ["alloc-try-no-release"]
    # the leak is in leaky(); disciplined() and untried() are clean
    assert findings[0].line < 18


def test_fixture_mesh_findings():
    findings = L.lint_file(FIXTURES / "bad_mesh.py")
    assert _rules(findings) == ["constrain-unknown-axis",
                                "jit-mesh-closure"]
    by_rule = {f.rule: f for f in findings}
    # the closure finding names the offending global; the axis finding
    # names the typo'd axis — and the known/non-literal calls are silent
    assert "'SHARDING'" in by_rule["jit-mesh-closure"].message
    assert "'heds'" in by_rule["constrain-unknown-axis"].message


def test_known_axes_registry_is_live():
    """The lint's axis registry is the real RULE_PRESETS vocabulary,
    not a drifting copy: every axis the serve presets map is known."""
    from repro.dist.sharding import KNOWN_LOGICAL_AXES, RULE_PRESETS
    assert L.KNOWN_LOGICAL_AXES == KNOWN_LOGICAL_AXES
    for rules in RULE_PRESETS.values():
        for axis, _ in rules.items():
            assert axis in L.KNOWN_LOGICAL_AXES


def test_pragma_suppresses_everything():
    assert L.lint_file(FIXTURES / "pragma_ok.py") == []


def test_src_repro_is_clean():
    findings, n_files = L.lint_paths([str(SRC)])
    assert n_files > 50  # the walk actually covered the package
    assert findings == [], "\n".join(
        f"{f.path}:{f.line} {f.rule}: {f.message}" for f in findings)


def test_checks_resolve_real_sites():
    """Zero findings must mean 'checked and clean', not 'skipped':
    the repo's jit wrappers and every pallas_call kernel resolve."""
    n_jit = n_pallas = n_index_maps = 0
    for path in sorted(SRC.rglob("*.py")):
        src = path.read_text(encoding="utf-8")
        fl = L._FileLinter(str(path), ast.parse(src), src)
        n_jit += sum(1 for _ in fl._jit_sites())
        for node in ast.walk(fl.tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "pallas_call"):
                n_pallas += 1
                assert fl._resolve_kernel(node.args[0]) is not None, path
                _, _, in_specs, out_specs, _, _ = \
                    fl._grid_spec_fields(node)
                n_index_maps += len(fl._index_maps(in_specs)
                                    + fl._index_maps(out_specs))
    assert n_jit >= 6        # ops.py wrappers + dryrun prefill_step
    assert n_pallas == 5     # one per kernel module
    assert n_index_maps >= 20


def test_cli_json_and_exit_codes(tmp_path):
    env_src = str(REPO / "src")
    clean = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--check",
         str(SRC / "analysis")],
        capture_output=True, text=True, cwd=str(REPO),
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"})
    assert clean.returncode == 0, clean.stderr
    doc = json.loads(clean.stdout)
    assert doc["findings"] == [] and doc["files_checked"] >= 3

    dirty = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--check",
         "--compact", str(FIXTURES)],
        capture_output=True, text=True, cwd=str(REPO),
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"})
    assert dirty.returncode == 1
    doc = json.loads(dirty.stdout)
    assert doc["n_errors"] > 0 and doc["n_warnings"] > 0
    # machine-readable contract: every finding carries the full schema
    for f in doc["findings"]:
        assert set(f) == {"rule", "severity", "path", "line", "col",
                          "message"}
        assert f["rule"] in L.RULES


def test_syntax_error_is_reported(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def oops(:\n", encoding="utf-8")
    findings = L.lint_file(bad)
    assert [f.rule for f in findings] == ["syntax-error"]


# ---------------------------------------------------------------------------
# PR 10: interprocedural dataflow rule families
# ---------------------------------------------------------------------------
def test_fixture_taint_findings():
    """Every planted leak fires determinism-taint at its line, and the
    OK blocks (timer→metric, seeded rng, sorted() laundering, the PR 8
    step-counted retune) stay silent."""
    findings = L.lint_file(FIXTURES / "bad_taint.py")
    assert {f.rule for f in findings} == {"determinism-taint"}
    assert sorted(f.line for f in findings) == [23, 29, 42, 43, 53, 61, 70]
    # the OK section starts at the timed_metrics block — nothing after it
    assert max(f.line for f in findings) < 73


def test_fixture_taint_messages_name_source_and_sink():
    by_line = {f.line: f.message for f in
               L.lint_file(FIXTURES / "bad_taint.py")}
    assert "select_victim" in by_line[23]
    assert "time.time" in by_line[23]
    assert "PRNGKey" in by_line[29]
    # the interprocedural chain names the intermediate helpers
    assert "default_rng" in by_line[42] or "_derive" in by_line[42]
    assert "set" in by_line[61].lower()  # set-iteration-order source


def test_fixture_trace_capture_findings():
    findings = L.lint_file(FIXTURES / "bad_trace_capture.py")
    got = sorted((f.line, f.rule) for f in findings)
    assert got == [(27, "jit-trace-capture"),
                   (33, "jit-host-effect"),
                   (34, "jit-host-effect"),
                   (34, "jit-trace-capture"),
                   (58, "jit-trace-capture")]
    # line 58 is the PR 9 regression shape: a bound method of a shared
    # model jitted under an ambient mesh — the message must point at it
    pr9 = next(f for f in findings if f.line == 58)
    assert "bound method" in pr9.message
    assert "decode_step" in pr9.message


def test_fixture_cache_lock_findings():
    findings = L.lint_file(FIXTURES / "bad_cache_lock.py")
    assert {f.rule for f in findings} == {"cache-lock-discipline"}
    assert sorted(f.line for f in findings) == [24, 26, 29]
    # the interprocedural part: _write's findings name the unlocked
    # entry point that reaches them
    assert all("put()" in f.message for f in findings)


def test_output_is_byte_identical_across_runs():
    """Determinism contract: two independent lints of the same tree
    produce byte-identical JSON (sorted findings, sorted keys)."""
    def run_once():
        findings, n = L.lint_paths([str(FIXTURES)])
        return json.dumps(
            {"files_checked": n,
             "findings": [f.to_dict() for f in findings]},
            sort_keys=True)

    assert run_once() == run_once()


def test_cli_github_format(tmp_path):
    env_src = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--check",
         "--format", "github", str(FIXTURES)],
        capture_output=True, text=True, cwd=str(REPO),
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1  # exit codes unchanged by the format
    lines = proc.stdout.strip().splitlines()
    ann = [ln for ln in lines if ln.startswith("::error")
           or ln.startswith("::warning")]
    assert ann, proc.stdout
    for ln in ann:
        assert re.match(
            r"^::(error|warning) file=[^,]+,line=\d+,col=\d+,"
            r"title=[a-z-]+::", ln), ln
    assert lines[-1].startswith("::notice title=lint::checked ")
    # byte-identical across runs, like the JSON format
    again = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--check",
         "--format", "github", str(FIXTURES)],
        capture_output=True, text=True, cwd=str(REPO),
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin"})
    assert again.stdout == proc.stdout
