"""Optimizer substrate tests: AdamW, schedules, clipping, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_grads,
    compression_init,
    global_norm,
    lr_at,
)


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0, 1.5])}
        target = jnp.array([1.0, 1.0, 1.0])
        cfg = OptimizerConfig(learning_rate=0.05, weight_decay=0.0,
                              warmup_steps=0, schedule="constant")
        state = adamw_init(params)

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2)

        for _ in range(300):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(g, state, params, cfg)
        assert float(loss(params)) < 1e-3

    def test_moments_are_f32(self):
        params = {"w": jnp.zeros((4,), jnp.bfloat16)}
        state = adamw_init(params)
        assert state["mu"]["w"].dtype == jnp.float32
        assert state["nu"]["w"].dtype == jnp.float32

    def test_weight_decay_shrinks(self):
        params = {"w": jnp.ones((8,)) * 10}
        cfg = OptimizerConfig(learning_rate=0.1, weight_decay=0.5,
                              warmup_steps=0, schedule="constant")
        state = adamw_init(params)
        zero_g = {"w": jnp.zeros((8,))}
        for _ in range(50):
            params, state, _ = adamw_update(zero_g, state, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1.0


class TestSchedule:
    def test_warmup_and_decay(self):
        cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=100,
                              total_steps=1000, schedule="cosine",
                              min_lr_ratio=0.1)
        assert float(lr_at(cfg, jnp.int32(0))) == 0.0
        assert float(lr_at(cfg, jnp.int32(50))) == pytest.approx(5e-4)
        assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(1e-3)
        end = float(lr_at(cfg, jnp.int32(1000)))
        assert end == pytest.approx(1e-4, rel=1e-3)

    @given(step=st.integers(0, 2000))
    @settings(max_examples=30, deadline=None)
    def test_lr_bounded(self, step):
        cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=10,
                              total_steps=1000)
        lr = float(lr_at(cfg, jnp.int32(step)))
        assert 0.0 <= lr <= 1e-3 + 1e-12


class TestClip:
    def test_clip_reduces_norm(self):
        tree = {"a": jnp.ones((10,)) * 100.0}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(np.sqrt(10) * 100)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_no_clip_below_threshold(self):
        tree = {"a": jnp.ones((4,)) * 0.1}
        clipped, _ = clip_by_global_norm(tree, 10.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(tree["a"]))


class TestCompression:
    @pytest.mark.parametrize("scheme", ["int8", "topk"])
    def test_error_feedback_is_unbiased_over_time(self, scheme):
        """EF guarantee: Σ applied_t ≈ Σ raw_t (residual stays bounded)."""
        rng = np.random.default_rng(0)
        params = {"w": jnp.zeros((64,))}
        error = compression_init(params, scheme)
        total_raw = np.zeros(64)
        total_applied = np.zeros(64)
        for _ in range(50):
            g = {"w": jnp.asarray(rng.normal(size=64), jnp.float32)}
            applied, error = compress_grads(g, error, scheme)
            total_raw += np.asarray(g["w"])
            total_applied += np.asarray(applied["w"])
        residual = np.abs(np.asarray(error["w"]))
        np.testing.assert_allclose(total_applied + np.asarray(error["w"]),
                                   total_raw, rtol=1e-4, atol=1e-4)
        assert residual.max() < 5.0  # residual bounded, not growing

    def test_int8_quantization_error_small(self):
        g = {"w": jnp.linspace(-1, 1, 255)}
        error = compression_init(g, "int8")
        applied, error = compress_grads(g, error, "int8")
        assert float(jnp.abs(applied["w"] - g["w"]).max()) < 1.0 / 127 + 1e-6

    def test_none_passthrough(self):
        g = {"w": jnp.ones(4)}
        out, err = compress_grads(g, None, "none")
        assert out is g and err is None

    def test_topk_sparsity(self):
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=100),
                              jnp.float32)}
        error = compression_init(g, "topk")
        applied, _ = compress_grads(g, error, "topk", topk_frac=0.05)
        nonzero = int((np.asarray(applied["w"]) != 0).sum())
        assert nonzero <= 6
