"""Paged KV allocator + runtime scheduler invariants (no jax needed)."""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.paging import (PAGE_TOKENS, OversubscriptionError,
                                PageAllocator, PrefixIndex)
from repro.serve.scheduler import Request, SlotScheduler, admission_order


def _reqs(lens, max_new=4):
    return [Request(i, list(range(1, n + 1)), max_new) for i, n in
            enumerate(lens)]


class TestPageAllocator:
    def test_alloc_release_balance(self):
        a = PageAllocator(n_pages=16, pages_per_group=1)
        assert a.usable_groups == 15
        g1 = a.try_alloc(0, 40)  # 3 pages
        g2 = a.try_alloc(1, 16)  # 1 page
        assert len(g1) == 3 and len(g2) == 1
        assert a.groups_in_use == 4
        assert not (set(g1) & set(g2))
        assert PageAllocator.SCRATCH_GROUP not in g1 + g2
        a.check_balanced()
        a.release(0)
        a.release(1)
        assert a.groups_in_use == 0
        a.check_balanced()

    def test_grouped_pages(self):
        a = PageAllocator(n_pages=16, pages_per_group=4)
        assert a.group_tokens == 4 * PAGE_TOKENS
        assert a.usable_groups == 3  # 4 groups minus scratch
        assert len(a.try_alloc(0, 65)) == 2  # 65 tokens -> 2 x 64-token groups

    def test_temporarily_full_returns_none(self):
        a = PageAllocator(n_pages=4, pages_per_group=1)
        assert a.try_alloc(0, 3 * PAGE_TOKENS) is not None
        assert a.try_alloc(1, PAGE_TOKENS) is None  # full, but fits later
        a.release(0)
        assert a.try_alloc(1, PAGE_TOKENS) is not None

    def test_oversubscription_raises(self):
        a = PageAllocator(n_pages=4, pages_per_group=1)
        with pytest.raises(OversubscriptionError, match="kv_cache_pages"):
            a.try_alloc(0, 4 * PAGE_TOKENS)  # > 3 usable groups, ever

    def test_double_alloc_and_unknown_release_rejected(self):
        a = PageAllocator(n_pages=8)
        a.try_alloc(0, 16)
        with pytest.raises(ValueError, match="already holds"):
            a.try_alloc(0, 16)
        with pytest.raises(KeyError):
            a.release(7)

    def test_mixed_length_stress_no_leaks(self):
        """Admit/release in interleaved order: every group returns home."""
        a = PageAllocator(n_pages=32, pages_per_group=2)
        live = {}
        for rid, tokens in enumerate([50, 17, 200, 33, 64, 1, 129, 96]):
            got = a.try_alloc(rid, tokens)
            if got is None:
                victim = next(iter(live))
                a.release(victim)
                live.pop(victim)
                got = a.try_alloc(rid, tokens)
            assert got is not None
            live[rid] = got
            a.check_balanced()
            if rid % 3 == 2:
                victim = next(iter(live))
                a.release(victim)
                live.pop(victim)
                a.check_balanced()
        for rid in list(live):
            a.release(rid)
        assert a.groups_in_use == 0
        assert a.high_water > 0
        a.check_balanced()

    def test_degenerate_pools_rejected(self):
        with pytest.raises(ValueError):
            PageAllocator(n_pages=1)  # scratch only
        with pytest.raises(ValueError):
            PageAllocator(n_pages=8, pages_per_group=0)

    def test_extend_grows_group_by_group(self):
        a = PageAllocator(n_pages=8, pages_per_group=1)
        first = a.try_alloc(0, 10)  # 1 group covers 16 tokens
        assert len(first) == 1
        assert a.extend(0, 16) == []      # still inside the reservation
        grew = a.extend(0, 17)            # crosses the group boundary
        assert len(grew) == 1 and grew[0] not in first
        assert a.owned_groups(0) == first + grew
        assert a.extend(0, 33) and len(a.owned_groups(0)) == 3
        a.check_balanced()
        a.release(0)
        a.check_balanced()

    def test_extend_none_when_full_oversubscription_raises(self):
        a = PageAllocator(n_pages=4, pages_per_group=1)  # 3 usable
        a.try_alloc(0, 16)
        a.try_alloc(1, 2 * 16)
        assert a.extend(0, 17) is None  # temporarily full: preempt + retry
        a.release(1)
        assert a.extend(0, 17) is not None
        with pytest.raises(OversubscriptionError, match="kv_cache_pages"):
            a.extend(0, 4 * 16)  # can never fit, even with the pool empty
        with pytest.raises(KeyError):
            a.extend(9, 16)  # unknown owner

    def test_extend_moves_high_water(self):
        a = PageAllocator(n_pages=8)
        a.try_alloc(0, 16)
        hw = a.high_water
        a.extend(0, 3 * 16)
        assert a.high_water == 3 > hw

    def test_release_all_unwinds_every_owner(self):
        a = PageAllocator(n_pages=16)
        a.try_alloc(0, 40)
        a.try_alloc(1, 16)
        a.extend(1, 32)
        assert a.release_all() == 2
        assert a.groups_in_use == 0
        a.check_balanced()
        assert a.release_all() == 0  # idempotent on an empty pool


class TestSlotScheduler:
    def test_fifo_preserves_arrival(self):
        s = SlotScheduler("fifo", 2)
        s.submit(_reqs([5, 3, 9, 1]))
        assert [s.pop().rid for _ in range(4)] == [0, 1, 2, 3]

    def test_sjf_orders_by_prompt_len_with_stable_ties(self):
        s = SlotScheduler("sjf", 2)
        s.submit(_reqs([5, 3, 9, 3]))
        assert [s.pop().rid for _ in range(4)] == [1, 3, 0, 2]

    def test_interleave_admits_fifo_but_flags_chunking(self):
        s = SlotScheduler("interleave", 2)
        assert s.interleave_prefill
        s.submit(_reqs([5, 3]))
        assert [s.pop().rid, s.pop().rid] == [0, 1]
        assert not SlotScheduler("fifo", 2).interleave_prefill

    def test_incremental_submission_keeps_policy_order(self):
        s = SlotScheduler("sjf", 1)
        s.submit(_reqs([8]))
        s.submit([Request(1, [1, 2], 4)])
        assert s.peek().rid == 1  # shorter prompt jumps the queue
        assert [s.pop().rid, s.pop().rid] == [1, 0]
        assert not s.has_pending

    def test_admission_order_function(self):
        """The plain-function view of the policy (what the surrogate's
        schedule terms assume; rank-agreement tests pin the rest)."""
        reqs = _reqs([4, 2, 6])
        assert [r.rid for r in admission_order("fifo", reqs)] == [0, 1, 2]
        assert [r.rid for r in admission_order("sjf", reqs)] == [1, 0, 2]
        with pytest.raises(ValueError, match="unknown schedule"):
            admission_order("lifo", reqs)

    def test_bad_policy_and_slots_rejected(self):
        with pytest.raises(ValueError, match="unknown schedule"):
            SlotScheduler("lifo", 2)
        with pytest.raises(ValueError):
            SlotScheduler("fifo", 0)

    def test_request_reservation_size(self):
        r = Request(0, [1, 2, 3], 5)
        assert r.prompt_len == 3
        assert r.total_tokens == 8
        assert r.resident_tokens == 3  # on_demand admits the prompt only
        r.generated = [7, 7]
        assert r.resident_tokens == 5  # re-prefill carries generated tokens
        assert r.total_tokens == 8     # worst case is unchanged

    def test_submit_assigns_arrival_once(self):
        """A preemption re-queue must not lose the original ordering:
        arrival is assigned on FIRST submission only."""
        s = SlotScheduler("fifo", 2)
        s.submit(_reqs([5, 3]))
        first = s.pop()
        assert first.arrival == 0
        s.submit([first])  # re-submission keeps arrival 0
        assert first.arrival == 0
        assert s.peek() is first  # fifo: back at the head, not the tail
        s.submit([Request(9, [1], 4)])
        assert [s.pop().rid for _ in range(3)] == [first.rid, 1, 9]

    def test_resubmit_jumps_the_queue(self):
        """Preempted requests re-enter at the head regardless of policy —
        they already spent prefill/decode work."""
        s = SlotScheduler("sjf", 2)
        s.submit(_reqs([3, 5, 9]))
        victim = s.pop()          # rid 0 (shortest)
        long_one = Request(7, list(range(20)), 4)
        victim.generated = [42]   # mid-flight state rides along
        s.resubmit(victim)
        s.submit([long_one])
        # head is the resubmitted victim even though sjf would rank the
        # pending 5-token prompt first
        assert s.peek() is victim
        assert s.pop() is victim
        assert [s.pop().rid for _ in range(3)] == [1, 2, 7]

    def test_pop_first_fit_bypasses_blocked_head(self):
        """The bounded sjf head-of-line bypass: admit the first FITTING
        pending request when the head's reservation does not fit."""
        s = SlotScheduler("sjf", 2)
        s.submit([Request(0, [1, 2], 30),      # head: huge max_new
                  Request(1, [1, 2, 3], 30),   # also too big
                  Request(2, [1, 2, 3, 4], 2)])  # fits
        got = s.pop_first_fit(lambda r: r.total_tokens <= 8)
        assert got is not None and got.rid == 2
        # head untouched; nothing else fits
        assert s.peek().rid == 0
        assert s.pop_first_fit(lambda r: r.total_tokens <= 8) is None
        # the window is bounded: a fitting request beyond it is not seen
        s2 = SlotScheduler("fifo", 2)
        s2.submit([Request(i, [1] * 4, 30) for i in range(5)]
                  + [Request(5, [1], 1)])
        assert s2.pop_first_fit(lambda r: r.total_tokens <= 2,
                                limit=4) is None
        assert s2.pop_first_fit(lambda r: r.total_tokens <= 2,
                                limit=6).rid == 5

    def test_pop_first_fit_scans_resubmitted_first(self):
        s = SlotScheduler("sjf", 2)
        s.submit(_reqs([5, 3]))
        victim = s.pop()
        s.resubmit(victim)
        got = s.pop_first_fit(lambda r: True)
        assert got is victim

    def test_select_victim_is_youngest(self):
        reqs = _reqs([4, 2, 6])
        SlotScheduler("fifo", 2).submit(reqs)
        assert SlotScheduler.select_victim(reqs).rid == 2  # last arrival
        assert SlotScheduler.select_victim(reqs[:1]).rid == 0
        with pytest.raises(ValueError):
            SlotScheduler.select_victim([])

    def test_select_victim_cost_aware_picks_cheapest_recompute(self):
        reqs = _reqs([4, 2, 6])
        SlotScheduler("fifo", 2).submit(reqs)
        costs = {0: 5, 1: 9, 2: 7}
        got = SlotScheduler.select_victim(reqs, cost=lambda r: costs[r.rid])
        assert got.rid == 0  # smallest re-prefill bill wins
        # equal cost falls back to the historical youngest-arrival rule
        assert SlotScheduler.select_victim(reqs, cost=lambda r: 3).rid == 2
        with pytest.raises(ValueError):
            SlotScheduler.select_victim([], cost=lambda r: 0)

    def test_page_policy_axis_validated(self):
        assert not SlotScheduler("fifo", 2).on_demand  # reserve default
        assert SlotScheduler("fifo", 2, page_policy="on_demand").on_demand
        with pytest.raises(ValueError, match="unknown page_policy"):
            SlotScheduler("fifo", 2, page_policy="lazy")


class TestPrefixSharingAllocator:
    def test_share_refcounts_and_either_release_order(self):
        a = PageAllocator(n_pages=8)
        donor = a.try_alloc(0, 32)  # 2 groups
        assert a.share(1, donor) == donor
        assert all(a.ref(g) == 2 for g in donor)
        assert a.groups_in_use == 2  # distinct physical groups, not 4
        a.check_balanced()
        a.release(0)  # donor leaves first: the sharer keeps the KV alive
        assert all(a.ref(g) == 1 for g in donor)
        assert a.owned_groups(1) == donor
        a.check_balanced()
        a.release(1)
        assert a.groups_in_use == 0
        a.check_balanced()

    def test_share_rejects_dead_scratch_and_double_owner(self):
        a = PageAllocator(n_pages=8)
        donor = a.try_alloc(0, 16)
        with pytest.raises(ValueError, match="already holds"):
            a.share(0, donor)
        with pytest.raises(ValueError, match="scratch"):
            a.share(1, [PageAllocator.SCRATCH_GROUP])
        a.release(0)
        with pytest.raises(ValueError, match="not live"):
            a.share(1, donor)  # freed groups can never be shared
        a.check_balanced()

    def test_cow_split_privatizes_one_logical_group(self):
        a = PageAllocator(n_pages=8)
        donor = a.try_alloc(0, 32)
        a.share(1, donor)
        new = a.cow_split(1, 1)
        assert new is not None and new != donor[1]
        assert a.owned_groups(1) == [donor[0], new]
        assert a.owned_groups(0) == donor  # donor's mapping is untouched
        assert a.ref(donor[1]) == 1 and a.ref(new) == 1
        assert a.ref(donor[0]) == 2  # leading group is still shared
        a.check_balanced()

    def test_cow_split_requires_sharing_and_free_space(self):
        a = PageAllocator(n_pages=4)  # 3 usable groups
        donor = a.try_alloc(0, 32)  # 2 groups
        a.share(1, donor)
        assert a.cow_split(1, 0) is not None  # takes the last free group
        assert a.cow_split(1, 1) is None      # pool full: preempt + retry
        with pytest.raises(ValueError, match="single owner"):
            a.cow_split(1, 0)  # already private
        with pytest.raises(KeyError):
            a.cow_split(9, 0)
        a.check_balanced()

    def test_shared_prefix_tokens_counts_leading_run_only(self):
        a = PageAllocator(n_pages=8)
        donor = a.try_alloc(0, 48)  # 3 groups
        a.share(1, donor)
        assert a.shared_prefix_tokens(1) == 48
        a.cow_split(1, 1)  # middle goes private: leading run is 1 group
        assert a.shared_prefix_tokens(1) == PAGE_TOKENS
        assert a.shared_prefix_tokens(0) == PAGE_TOKENS  # symmetric view
        a.release(1)
        assert a.shared_prefix_tokens(0) == 0
        with pytest.raises(KeyError):
            a.shared_prefix_tokens(9)

    def test_generation_bumps_only_on_free(self):
        a = PageAllocator(n_pages=4)
        g = a.try_alloc(0, 16)[0]
        gen = a.generation(g)
        a.share(1, [g])
        a.release(0)
        assert a.generation(g) == gen  # still live via the sharer
        a.release(1)
        assert a.generation(g) == gen + 1  # actually freed: aged


class TestPrefixIndex:
    def test_register_then_chain_match(self):
        a = PageAllocator(n_pages=16)
        idx = PrefixIndex(a)
        prompt = list(range(40))  # 2 full chunks + 8-token tail
        gids = a.try_alloc(0, len(prompt))
        assert idx.register(prompt, gids) == 2  # full chunks only
        hit, covered = idx.match(prompt)
        assert covered == 32 and hit == gids[:2]
        # divergence mid-chunk shares only the whole chunks before it
        hit, covered = idx.match(prompt[:20] + [999] * 12)
        assert covered == 16 and hit == gids[:1]
        # a different first token shares nothing
        assert idx.match([999] + prompt[1:]) == ([], 0)

    def test_boundary_share_of_trailing_partial_chunk(self):
        a = PageAllocator(n_pages=16)
        idx = PrefixIndex(a)
        prompt = list(range(32))
        gids = a.try_alloc(0, 32)
        idx.register(prompt, gids)
        # a shorter prompt that is a prefix of a registered chunk covers
        # its own partial tail (the caller CoWs that final group)
        hit, covered = idx.match(prompt[:24])
        assert covered == 24 and hit == gids[:2]

    def test_stale_entries_never_match(self):
        a = PageAllocator(n_pages=4)
        idx = PrefixIndex(a)
        prompt = list(range(16))
        gids = a.try_alloc(0, 16)
        idx.register(prompt, gids)
        a.release(0)
        assert idx.match(prompt) == ([], 0)  # freed: pruned
        regot = a.try_alloc(1, 16)
        assert regot == gids  # the pool recycled the same physical group
        assert idx.match(prompt) == ([], 0)  # generation mismatch: stale

    def test_first_registration_wins(self):
        a = PageAllocator(n_pages=8)
        idx = PrefixIndex(a)
        prompt = list(range(16))
        g0 = a.try_alloc(0, 16)
        g1 = a.try_alloc(1, 16)
        assert idx.register(prompt, g0) == 1
        assert idx.register(prompt, g1) == 0  # duplicate content skipped
        assert idx.match(prompt)[0] == g0


class TestSharingInterleavingProperties:
    """Property sweep over random alloc/share/extend/CoW/release
    interleavings (hypothesis draws the walk parameters; the conftest
    stub supplies a deterministic drop-in when the real package is not
    installed).  After EVERY step the pool must stay balanced — no group
    lost, duplicated or left with a drifted refcount — and distinct
    physical residency can never exceed the sum of logical
    reservations."""

    @settings(max_examples=30, deadline=None)
    @given(st.integers(min_value=0, max_value=10**6),
           st.sampled_from([4, 8, 16, 32]),
           st.sampled_from([1, 2]))
    def test_random_interleavings_stay_balanced(self, seed, pages, ppg):
        rng = random.Random(seed)
        a = PageAllocator(n_pages=pages, pages_per_group=ppg)
        live = {}  # owner -> reserved token count
        next_owner = 0
        for _ in range(200):
            op = rng.random()
            if op < 0.35 or not live:
                toks = rng.randrange(1, a.usable_tokens + 1)
                if a.try_alloc(next_owner, toks) is not None:
                    live[next_owner] = toks
                    next_owner += 1
            elif op < 0.50:  # share a donor's leading groups
                donor = rng.choice(sorted(live))
                gids = a.owned_groups(donor)
                k = rng.randrange(1, len(gids) + 1)
                a.share(next_owner, gids[:k])
                live[next_owner] = k * a.group_tokens
                next_owner += 1
            elif op < 0.65:  # CoW a shared logical position
                owner = rng.choice(sorted(live))
                gids = a.owned_groups(owner)
                j = rng.randrange(len(gids))
                if a.ref(gids[j]) >= 2:
                    a.cow_split(owner, j)  # None (pool full) is fine
            elif op < 0.80:  # on-demand growth
                owner = rng.choice(sorted(live))
                want = live[owner] + rng.randrange(1, 2 * a.group_tokens)
                try:
                    if a.extend(owner, want) is not None:
                        live[owner] = want
                except OversubscriptionError:
                    pass  # pool can never hold it — legal, loud, no-op
            else:  # preemption/completion: release mid-flight
                owner = rng.choice(sorted(live))
                a.release(owner)
                del live[owner]
            a.check_balanced()  # refs exact, no dup/lost/scratch groups
            logical = sum(len(a.owned_groups(o)) for o in live)
            assert a.groups_in_use <= logical
            assert all(a.ref(g) >= 1
                       for o in live for g in a.owned_groups(o))
        for owner in sorted(live):
            a.release(owner)
        a.check_balanced()
        assert a.groups_in_use == 0
