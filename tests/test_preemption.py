"""Property tests for on-demand page growth + the preemption parity matrix.

Two layers of the PR-5 contract:

* ``PageAllocator`` under random ``try_alloc``/``extend``/``release``
  interleavings (hypothesis, or the deterministic stub): the pool stays
  balanced, the scratch group never leaks into a reservation, and the
  high-water mark is monotone.
* The engine matrix: per-request tokens are bit-identical across page
  policies (``reserve``/``on_demand``), all three schedules, paged/dense
  layouts, and with/without forced preemption — the tuned knobs move
  *when* work happens, never *what* is generated.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve.paging import (OversubscriptionError, PAGE_TOKENS,
                                PageAllocator)

# ---------------------------------------------------------------------------
# allocator property tests (no jax)
# ---------------------------------------------------------------------------


class TestAllocatorProperties:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n_pages=st.integers(4, 40),
           pages_per_group=st.integers(1, 3))
    def test_random_interleavings_stay_balanced(self, seed, n_pages,
                                                pages_per_group):
        """alloc/extend/release in random order: balance invariant after
        every operation, no scratch leakage, high-water monotone."""
        if n_pages // pages_per_group < 2:
            n_pages = 2 * pages_per_group  # keep the pool constructible
        a = PageAllocator(n_pages, pages_per_group=pages_per_group)
        rng = np.random.default_rng(seed)
        live = {}  # owner -> tokens currently reserved
        next_owner = 0
        hw = a.high_water
        for _ in range(60):
            op = rng.integers(0, 3)
            if op == 0:  # admit a new owner
                tokens = int(rng.integers(1, a.usable_tokens + 1))
                try:
                    got = a.try_alloc(next_owner, tokens)
                except OversubscriptionError:
                    got = None
                if got is not None:
                    assert PageAllocator.SCRATCH_GROUP not in got
                    assert len(got) == a.groups_for(tokens)
                    live[next_owner] = tokens
                    next_owner += 1
            elif op == 1 and live:  # grow a live owner
                owner = int(rng.choice(list(live)))
                grow_to = live[owner] + int(rng.integers(1, 2 * a.group_tokens))
                try:
                    new = a.extend(owner, grow_to)
                except OversubscriptionError:
                    new = None
                if new is not None:
                    assert PageAllocator.SCRATCH_GROUP not in new
                    live[owner] = grow_to
                    assert len(a.owned_groups(owner)) == \
                        a.groups_for(grow_to)
            elif op == 2 and live:  # complete (or preempt) an owner
                owner = int(rng.choice(list(live)))
                a.release(owner)
                del live[owner]
            a.check_balanced()
            assert a.high_water >= hw  # monotone
            hw = a.high_water
            assert a.free_groups + a.groups_in_use == a.usable_groups
        for owner in list(live):
            a.release(owner)
        assert a.groups_in_use == 0
        a.check_balanced()

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_extend_equals_upfront_reservation(self, seed):
        """Growing token-by-token lands on exactly the same group count
        as one worst-case reservation (no on-demand over-allocation)."""
        rng = np.random.default_rng(seed)
        total = int(rng.integers(1, 6 * PAGE_TOKENS))
        start = int(rng.integers(1, total + 1))
        a = PageAllocator(16)
        b = PageAllocator(16)
        a.try_alloc(0, total)
        b.try_alloc(0, start)
        for t in range(start + 1, total + 1):
            assert b.extend(0, t) is not None
        assert len(b.owned_groups(0)) == len(a.owned_groups(0))


# ---------------------------------------------------------------------------
# engine preemption parity matrix (jax)
# ---------------------------------------------------------------------------

# decode-heavy mixed workload: worst-case footprints (2 groups each at
# PAGE_TOKENS=16) oversubscribe the tiny pool, forcing on_demand
# preemption; expected footprints still pack several prompts
MATRIX_PROMPTS = [[1, 2, 3], [9, 8, 7, 6], [2, 2, 2, 2, 2],
                  [7, 1, 4, 1], [3, 3, 3, 3], [5, 4, 3, 2, 1, 6]]
MATRIX_NEW = [14, 12, 16, 13, 18, 12]
TINY_POOL = 4   # pages: 3 usable groups -> reserve serializes admission
BIG_POOL = 16   # pages: every worst case resident, preemption impossible


@pytest.fixture(scope="module")
def engine():
    import jax

    from repro.configs import ModelConfig
    from repro.models import Model

    cfg = ModelConfig(
        name="tiny-preempt", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
        param_dtype="float32", compute_dtype="float32",
        vocab_pad_multiple=64, rope_theta=10_000.0)
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _generate(engine, layout, policy, schedule, pages):
    from repro.serve import ServeConfig, ServeEngine

    model, params = engine
    eng = ServeEngine(model, params, ServeConfig(
        max_seq=32, batch_slots=3, runtime="continuous", prefill_chunk=4,
        kv_layout=layout, page_policy=policy, schedule=schedule,
        kv_cache_pages=pages if layout == "paged" else None))
    res = eng.generate(MATRIX_PROMPTS, MATRIX_NEW)
    if layout == "paged":
        assert eng.last_alloc.groups_in_use == 0, \
            f"leak in {layout}/{policy}/{schedule}/pages={pages}"
        eng.last_alloc.check_balanced()
    return res


class TestPreemptionParityMatrix:
    def test_tokens_identical_across_the_matrix(self, engine):
        """reserve/on_demand x fifo/sjf/interleave x paged(+dense control)
        x oversubscribed/comfortable pools: one token stream."""
        ref = _generate(engine, "dense", "reserve", "fifo", None)
        preempted = 0
        for policy in ("reserve", "on_demand"):
            for schedule in ("fifo", "sjf", "interleave"):
                res = _generate(engine, "paged", policy, schedule,
                                TINY_POOL)
                assert res.tokens == ref.tokens, \
                    f"{policy}/{schedule} diverged on the tiny pool"
                if policy == "on_demand":
                    preempted += res.preemptions
                else:
                    assert res.preemptions == 0
        # the tiny pool must actually exercise the recompute path
        assert preempted > 0
        # comfortable pool: both policies, no preemption, same tokens
        for policy in ("reserve", "on_demand"):
            res = _generate(engine, "paged", policy, "fifo", BIG_POOL)
            assert res.tokens == ref.tokens
            assert res.preemptions == 0
        # dense control: policy knob is inert off the paged layout
        res = _generate(engine, "dense", "on_demand", "fifo", None)
        assert res.tokens == ref.tokens and res.preemptions == 0

    def test_preemption_survives_interleave_chunking(self, engine):
        """interleave + on_demand: a victim preempted mid-decode while
        another slot is still prefilling re-enters and completes with
        identical tokens (chunked re-prefill is exact)."""
        ref = _generate(engine, "paged", "reserve", "interleave", BIG_POOL)
        res = _generate(engine, "paged", "on_demand", "interleave",
                        TINY_POOL)
        assert res.preemptions > 0
        assert res.tokens == ref.tokens
