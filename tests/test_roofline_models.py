"""Consistency tests for the roofline inputs: MODEL_FLOPS, the analytic
memory model, and the knob space."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core.sut_jax import knob_space, knobs_from_config
from repro.train.step import RunKnobs
from repro.utils.flops import active_params, model_flops
from repro.utils.memory_model import analytic_memory_bytes

MESH = {"data": 16, "model": 16}


class TestModelFlops:
    def test_moe_active_below_total(self):
        for arch in ("mixtral-8x22b", "grok-1-314b"):
            cfg = get_config(arch)
            from repro.models import count_params

            assert active_params(cfg) < 0.5 * count_params(cfg)

    def test_dense_active_equals_total(self):
        cfg = get_config("gemma-7b")
        from repro.models import count_params

        assert active_params(cfg) == count_params(cfg)

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_flops_ordering(self, arch):
        """train (6ND) > prefill (2ND) >> decode (2N·B) for every arch."""
        cfg = get_config(arch)
        tr = model_flops(cfg, SHAPES["train_4k"])
        pf = model_flops(cfg, SHAPES["prefill_32k"])
        dc = model_flops(cfg, SHAPES["decode_32k"])
        assert tr == pytest.approx(3 * pf)  # same token count, 6ND vs 2ND
        assert dc < pf / 1000


class TestMemoryModel:
    def test_remat_reduces_activations(self):
        cfg = get_config("gemma-7b")
        rules = RunKnobs().axis_rules()
        m_none = analytic_memory_bytes(cfg, SHAPES["train_4k"], rules=rules,
                                       mesh_shape=MESH, remat="none")
        m_full = analytic_memory_bytes(cfg, SHAPES["train_4k"], rules=rules,
                                       mesh_shape=MESH, remat="full")
        assert m_full["activations"] < m_none["activations"] / 4
        assert m_full["weights"] > m_none["weights"]  # recompute re-streams

    def test_microbatches_scale_weight_traffic(self):
        cfg = get_config("gemma-7b")
        rules = RunKnobs().axis_rules()
        m1 = analytic_memory_bytes(cfg, SHAPES["train_4k"], rules=rules,
                                   mesh_shape=MESH, microbatches=1)
        m4 = analytic_memory_bytes(cfg, SHAPES["train_4k"], rules=rules,
                                   mesh_shape=MESH, microbatches=4)
        assert m4["weights"] == pytest.approx(4 * m1["weights"])

    def test_swa_bounds_decode_cache(self):
        mix = get_config("mixtral-8x22b")
        grok = get_config("grok-1-314b")
        rules = RunKnobs().axis_rules()
        m_mix = analytic_memory_bytes(mix, SHAPES["decode_32k"], rules=rules,
                                      mesh_shape=MESH)
        m_grok = analytic_memory_bytes(grok, SHAPES["decode_32k"],
                                       rules=rules, mesh_shape=MESH)
        # mixtral window 4096 vs grok full 32k cache (similar widths)
        assert m_mix["kv_cache_read"] < m_grok["kv_cache_read"] / 4

    def test_dp_all_batch_axes(self):
        """dp_all maps batch over the model axis too (regression: the
        fsdp_all feasibility bug found during the qwen hillclimb)."""
        cfg = get_config("qwen2.5-32b")
        rules = RunKnobs(rules_preset="fsdp_all").axis_rules()
        m = analytic_memory_bytes(cfg, SHAPES["train_4k"], rules=rules,
                                  mesh_shape=MESH, microbatches=1)
        rules16 = RunKnobs(rules_preset="fsdp_tp").axis_rules()
        m16 = analytic_memory_bytes(cfg, SHAPES["train_4k"], rules=rules16,
                                    mesh_shape=MESH, microbatches=1)
        assert m["activations"] == pytest.approx(m16["activations"] / 16)


class TestKnobSpace:
    def test_round_trips_to_runknobs(self):
        space = knob_space("train")
        cfg = space.default_config()
        knobs = knobs_from_config(cfg)
        assert isinstance(knobs, RunKnobs)
        assert knobs.rules_preset == "fsdp_tp"

    def test_decode_space_drops_trainer_knobs(self):
        space = knob_space("decode")
        assert "remat" not in space.names
        assert "kv_seq_shard" in space.names

    def test_all_samples_valid(self):
        space = knob_space("train")
        rng = np.random.default_rng(0)
        for _ in range(50):
            cfg = space.random_config(rng)
            knobs = knobs_from_config(cfg)
            knobs.axis_rules()  # must not raise
