"""Sharded multi-device serving (tensor-parallel decode over a mesh).

Three layers:

* pure in-process: the v4 cache's mesh-signature keys (spellings,
  v3 migration, nearest-mesh warm-start donors) and the co-deployment
  surrogate's communication/replica terms — including the exact
  n_devices=1 reduction to the historical formulas and the knob -> mesh
  mapping ``apply_serve_knobs`` performs,
* rank pinning: the surrogate's replicas-vs-TP preference directions
  are asserted against REAL engine step counts measured in the
  subprocess matrix (replicas widen capacity and cut decode dispatches;
  TP never changes the dispatch count),
* subprocess (8 fake XLA host devices — the flag must precede any jax
  import, hence the subprocess; ``ci.sh --fast`` excludes ``subprocess``
  tests): bit-identical token parity across meshes × kv layouts ×
  schedules, under recompute preemption, under temperature sampling,
  and across a mid-run online retune composing with an active mesh.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.autotune import (
    AutotuneCache,
    mesh_sig,
    nearest_mesh_serve_config,
    put_serve_config,
)
from repro.autotune.cache import mesh_distance, nearest_mesh, parse_mesh_sig

REPO = Path(__file__).resolve().parent.parent
SIG = {"S": 32, "H": 8, "KV": 4, "D": 8}


# ---------------------------------------------------------------------------
# mesh signatures + v4 cache keys
# ---------------------------------------------------------------------------
class TestMeshSignatures:
    def test_single_device_spellings_collapse(self):
        assert mesh_sig(None) == "1dev"
        assert mesh_sig((1, 1)) == "1dev"
        assert mesh_sig("1dev") == "1dev"
        with pytest.raises(ValueError):
            mesh_sig("not-a-mesh")

    def test_shape_roundtrip(self):
        assert mesh_sig((2, 4)) == "d2m4"
        assert parse_mesh_sig("d2m4") == (2, 4)
        assert parse_mesh_sig("1dev") == (1, 1)
        assert parse_mesh_sig("bogus") is None

    def test_distance_is_log2_gap(self):
        assert mesh_distance("d2m4", "d2m4") == 0.0
        assert mesh_distance("1dev", "d2m1") == 1.0
        assert mesh_distance("d2m1", "d8m1") == 2.0
        assert mesh_distance("d1m4", "d4m1") == 4.0
        assert mesh_distance("d2m4", "1dev") \
            == mesh_distance("1dev", "d2m4")

    def test_nearest_mesh_sorted_tie_break(self):
        # "1dev" and "d4m1" tie at distance 1 from d2m1: sorted order
        # (deterministic across runs) picks "1dev"
        got = nearest_mesh(["d4m1", "1dev"], "d2m1")
        assert got == ("1dev", 1.0)
        assert nearest_mesh([], "d2m1") is None


class TestMeshCacheKeys:
    def test_put_keys_carry_mesh_component(self, tmp_path):
        cache = AutotuneCache(str(tmp_path / "c.json"))
        put_serve_config(SIG, "float32", {"max_batch": 4}, 10.0,
                         cache=cache, mesh="d1m2")
        (key,) = list(cache._load())
        parts = key.split("|")
        assert len(parts) == 7 and parts[-1] == "d1m2"
        assert parts[0] == "v4" and parts[1] == "serve_engine"

    def test_v3_keys_migrate_to_1dev(self, tmp_path):
        path = tmp_path / "c.json"
        path.write_text(json.dumps({
            "v3|serve_engine|D8_H8_KV4_S32|float32|cpu|-":
                {"config": {"max_batch": 2}, "value": 1.0}}))
        cache = AutotuneCache(str(path))
        got = cache.get("serve_engine", "D8_H8_KV4_S32", "float32", "cpu")
        assert got is not None and got["config"] == {"max_batch": 2}
        assert all(k.split("|")[-1] == "1dev" for k in cache._load())

    def test_topologies_do_not_clobber(self, tmp_path):
        cache = AutotuneCache(str(tmp_path / "c.json"))
        for mesh, batch in (("", 2), ("d1m2", 4), ("d8m1", 16)):
            put_serve_config(SIG, "float32", {"max_batch": batch}, 1.0,
                             cache=cache, mesh=mesh)
        meshes = cache.scan_meshes("serve_engine",
                                   "D8_H8_KV4_S32", "float32",
                                   next(iter(cache._load())).split("|")[4])
        assert set(meshes) == {"1dev", "d1m2", "d8m1"}
        assert meshes["d8m1"]["config"]["max_batch"] == 16

    def test_nearest_mesh_donor_annotated(self, tmp_path):
        cache = AutotuneCache(str(tmp_path / "c.json"))
        from repro.autotune import backend_name
        be = backend_name()
        put_serve_config(SIG, "float32", {"max_batch": 4}, 1.0,
                         cache=cache, backend=be, mesh="d1m2")
        exact = nearest_mesh_serve_config(SIG, "float32", "d1m2",
                                          cache=cache, backend=be)
        assert exact["mesh_distance"] == 0.0
        assert exact["donor_mesh"] == "d1m2"
        # miss at d1m8: the d1m2 winner transfers as an annotated donor
        donor = nearest_mesh_serve_config(SIG, "float32", "d1m8",
                                          cache=cache, backend=be)
        assert donor["config"] == {"max_batch": 4}
        assert donor["donor_mesh"] == "d1m2"
        assert donor["mesh_distance"] == 2.0
        assert nearest_mesh_serve_config(
            {"S": 99, "H": 1, "KV": 1, "D": 1}, "float32", "d1m8",
            cache=cache, backend=be) is None


# ---------------------------------------------------------------------------
# surrogate communication/replica terms
# ---------------------------------------------------------------------------
BASE_KNOBS = dict(max_batch=8, prefill_chunk=512, kv_cache_pages=1024,
                  schedule="fifo", page_policy="on_demand",
                  share_prefix=1, draft_len=2)


def _score(n_dev, mode, *, n_requests=64, **params_kw):
    from repro.serve.space import CotuneParams, coupled_serve_metrics

    p = CotuneParams(n_requests=n_requests, **params_kw)
    kcfg = p.kernel_space().default_config()
    cfg = dict(BASE_KNOBS)
    if n_dev is not None:
        cfg.update(mesh_devices=n_dev, tp_vs_replicas=mode)
    return coupled_serve_metrics(cfg, kcfg, p)


class TestSurrogateMeshTerms:
    def test_single_device_reduces_exactly(self):
        legacy = _score(None, "tp")
        one = _score(1, "tp")
        assert legacy.value == pytest.approx(one.value, rel=1e-12)
        assert one.metrics["comm_s"] == 0.0

    def test_comm_floor_charges_tp_only(self):
        tp = _score(8, "tp")
        rep = _score(8, "replicas")
        assert tp.metrics["comm_s"] > 0.0
        assert rep.metrics["comm_s"] == 0.0
        # the per-hop all-reduce bill grows with the ring factor
        assert _score(8, "tp").metrics["comm_s"] \
            > _score(2, "tp").metrics["comm_s"]

    def test_replicas_win_under_queue_pressure(self):
        """Heavy queue: replicas multiply resident capacity (the engine
        measurably cuts decode dispatches — see the subprocess matrix);
        TP only shrinks per-step time and pays the all-reduce floor."""
        rep = _score(8, "replicas", n_requests=64)
        tp = _score(8, "tp", n_requests=64)
        assert rep.value > tp.value

    def test_tp_wins_when_queue_is_light(self):
        """Few requests: extra replica capacity idles (the engine's
        dispatch count is already minimal), while TP still divides the
        weight stream and attention."""
        rep = _score(8, "replicas", n_requests=4)
        tp = _score(8, "tp", n_requests=4)
        assert tp.value > rep.value

    def test_non_dividing_heads_lose_the_attention_win(self):
        from dataclasses import replace

        from repro.serve.space import CotuneParams, coupled_serve_metrics
        even = _score(8, "tp", n_requests=4)
        odd_p = replace(CotuneParams(n_requests=4), heads=12)
        cfg = dict(BASE_KNOBS, mesh_devices=8, tp_vs_replicas="tp")
        odd = coupled_serve_metrics(cfg, odd_p.kernel_space()
                                    .default_config(), odd_p)
        # 12 % 8 != 0: attention replicates — TP keeps only the
        # weight-stream division, so the step gets strictly slower
        assert odd.metrics["step_s"] > even.metrics["step_s"]

    def test_space_widens_only_on_request(self):
        from repro.serve.space import serve_knob_space

        legacy = serve_knob_space()
        assert "mesh_devices" not in legacy.names
        wide = serve_knob_space(max_devices=8)
        assert set(wide.names) >= set(legacy.names) \
            | {"mesh_devices", "tp_vs_replicas"}
        assert tuple(wide["mesh_devices"].choices) == (1, 2, 4, 8)

    def test_apply_knobs_maps_mode_to_mesh(self):
        from repro.serve.engine import ServeConfig
        from repro.serve.space import apply_serve_knobs

        base = ServeConfig(runtime="continuous", kv_layout="paged")
        cfg = dict(BASE_KNOBS, mesh_devices=8, tp_vs_replicas="tp")
        assert apply_serve_knobs(cfg, base=base).mesh_shape == (1, 8)
        cfg["tp_vs_replicas"] = "replicas"
        assert apply_serve_knobs(cfg, base=base).mesh_shape == (8, 1)
        # an explicit 1 CLEARS an inherited mesh; an absent knob keeps it
        sharded = ServeConfig(runtime="continuous", kv_layout="paged",
                              mesh_shape=(2, 2))
        assert apply_serve_knobs(dict(BASE_KNOBS, mesh_devices=1),
                                 base=sharded).mesh_shape is None
        assert apply_serve_knobs(dict(BASE_KNOBS),
                                 base=sharded).mesh_shape == (2, 2)


# ---------------------------------------------------------------------------
# the engine itself, on 8 fake devices (subprocess: XLA_FLAGS must
# precede any jax import)
# ---------------------------------------------------------------------------
_MATRIX = textwrap.dedent(r"""
    import json, os, sys
    import jax, numpy as np
    from repro.configs import ModelConfig
    from repro.models import Model
    from repro.serve import ServeConfig, ServeEngine

    assert len(jax.devices()) == 8, jax.devices()
    cfg = ModelConfig(
        name="shard-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=512, head_dim=8,
        param_dtype="float32", compute_dtype="float32",
        vocab_pad_multiple=64, rope_theta=10_000.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 512, size=n).tolist()
               for n in rng.integers(2, 20, size=12)]
    gens = [int(g) for g in rng.integers(2, 10, size=12)]

    def run(mesh=None, layout="paged", sched="fifo", temp=0.0):
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=2, runtime="continuous",
            kv_layout=layout, schedule=sched, prefill_chunk=4,
            temperature=temp, seed=0, mesh_shape=mesh))
        res = eng.generate(prompts, gens)
        if eng.last_alloc is not None:
            assert eng.last_alloc.groups_in_use == 0, (mesh, layout, "leak")
            eng.last_alloc.check_balanced()
        return res

    out = {"steps": {}}
    base = run()
    out["base_steps"] = base.steps
    arms = {
        "tp2_paged":    dict(mesh=(1, 2)),
        "tp8_paged":    dict(mesh=(1, 8)),
        "rep2_paged":   dict(mesh=(2, 1)),
        "rep8_paged":   dict(mesh=(8, 1)),
        "grid22_sjf":   dict(mesh=(2, 2), sched="sjf"),
        "tp2_dense_il": dict(mesh=(1, 2), layout="dense",
                             sched="interleave"),
        "grid22_dense": dict(mesh=(2, 2), layout="dense"),
    }
    for name, kw in arms.items():
        res = run(**kw)
        assert res.tokens == base.tokens, f"{name}: tokens diverged"
        out["steps"][name] = res.steps
    sampled = run(temp=0.8)
    assert run(mesh=(1, 2), temp=0.8).tokens == sampled.tokens, \
        "sampled tokens diverged under TP"

    # recompute preemption on a starved sharded pool: tokens must match
    # the unsharded fully-reserved oracle bit-for-bit
    p2 = [rng.integers(1, 512, size=n).tolist()
          for n in rng.integers(3, 9, size=8)]
    g2 = [int(g) for g in rng.integers(10, 17, size=8)]
    def run2(mesh, policy, pages):
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=3, runtime="continuous",
            kv_layout="paged", kv_cache_pages=pages, page_policy=policy,
            prefill_chunk=4, seed=0, mesh_shape=mesh))
        res = eng.generate(p2, g2)
        assert eng.last_alloc.groups_in_use == 0, "preempt arm leak"
        eng.last_alloc.check_balanced()
        return res
    oracle = run2(None, "reserve", None)
    pre = run2((1, 2), "on_demand", 4)
    assert pre.tokens == oracle.tokens, "preemption diverged under TP"
    out["preemptions"] = pre.preemptions
    json.dump(out, sys.stdout)
""")

_TRACEKEY = textwrap.dedent(r"""
    import json, sys
    import jax, numpy as np
    from repro.configs import ModelConfig
    from repro.models import Model
    from repro.serve import ServeConfig, ServeEngine

    cfg = ModelConfig(
        name="shard-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=512, head_dim=8,
        param_dtype="float32", compute_dtype="float32",
        vocab_pad_multiple=64, rope_theta=10_000.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 512, size=n).tolist()
               for n in rng.integers(2, 20, size=12)]
    gens = [int(g) for g in rng.integers(2, 10, size=12)]

    def run(mesh):
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=4, runtime="continuous",
            kv_layout="paged", kv_cache_pages=24, prefill_chunk=4,
            seed=0, mesh_shape=mesh))
        return eng.generate(prompts, gens)

    # a (2,1) and a (2,2) mesh both widen slots x2, so every jitted
    # step's avals coincide; the shared Model's bound methods hash
    # equal, so without per-engine trace keying the second engine
    # inherits jaxprs whose constraints pin the FIRST engine's devices
    base = run(None)
    toks = {m: run(m).tokens for m in ((2, 1), (2, 2), (2, 1))}
    assert all(t == base.tokens for t in toks.values()), "tokens diverged"
    json.dump({"ok": True}, sys.stdout)
""")

_RETUNE = textwrap.dedent(r"""
    import json, sys
    import jax, numpy as np
    from repro import autotune
    from repro.configs import ModelConfig
    from repro.models import Model
    from repro.serve import ServeConfig, ServeEngine
    from repro.serve.workload import fingerprint_sig

    cfg = ModelConfig(
        name="shard-tiny", family="dense", n_layers=2, d_model=64,
        n_heads=8, n_kv_heads=4, d_ff=128, vocab_size=512, head_dim=8,
        param_dtype="float32", compute_dtype="float32",
        vocab_pad_multiple=64, rope_theta=10_000.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    MESH = dict(mesh_shape=(1, 2))
    BASE = dict(max_seq=48, batch_slots=8, kv_layout="paged", seed=0,
                prefill_chunk=8, slot_cap=3)
    RETUNE = dict(retune=True, retune_budget=8, retune_threshold=0.3,
                  retune_window=10, retune_cooldown=200,
                  retune_check_every=2, retune_min_requests=6)

    rng = np.random.default_rng(0)
    pa = [rng.integers(1, 500, size=20).tolist() for _ in range(6)]
    eng = ServeEngine(model, params, ServeConfig(
        **BASE, **MESH, retune=True, retune_threshold=10.0,
        retune_min_requests=6, retune_window=10))
    eng.generate(pa, [12] * 6)
    sig_a = fingerprint_sig(eng.last_retuner.baseline)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 500, size=20).tolist() for _ in range(3)]
    shared = rng.integers(1, 500, size=32).tolist()
    prompts += [shared + rng.integers(1, 500, size=3).tolist()
                for _ in range(12)]
    gens = [12] * 3 + [6] * 12

    autotune.reset_default_cache()
    eng = ServeEngine(model, params, ServeConfig(
        **BASE, **MESH, tuned_signature=sig_a, **RETUNE))
    res = eng.generate(prompts, gens)
    eng.last_alloc.check_balanced()
    # oracles: same mesh without retuning, and no mesh at all
    ref_mesh = ServeEngine(model, params, ServeConfig(
        **BASE, **MESH)).generate(prompts, gens)
    ref_1dev = ServeEngine(model, params, ServeConfig(
        **BASE)).generate(prompts, gens)
    assert res.tokens == ref_mesh.tokens == ref_1dev.tokens, \
        "mid-run retune on an active mesh changed tokens"
    keys = [k for k in autotune.default_cache()._load()
            if "serve_engine" in k]
    json.dump({"retunes": len(res.retunes),
               "applied": bool(res.retunes and res.retunes[0]["applied"]),
               "serve_keys": keys}, sys.stdout)
""")


def _run_sub(script, tmp_path, n_devices=8):
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src"),
               REPRO_AUTOTUNE_CACHE=str(tmp_path / "cache.json"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count"
                         f"={n_devices}")
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, cwd=str(REPO),
                          env=env, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout)


class TestShardedParitySubprocess:
    def test_parity_matrix_subprocess(self, tmp_path):
        out = _run_sub(_MATRIX, tmp_path)
        steps, base = out["steps"], out["base_steps"]
        # TP dispatch invariant: one batched decode dispatch per step,
        # so widening the model axis never changes the dispatch count
        assert steps["tp2_paged"] == steps["tp8_paged"] == base
        assert steps["tp2_dense_il"] >= 1
        # replicas widen slot capacity: dispatch count strictly drops,
        # monotonically in the data-axis width — the direction the
        # surrogate's replica terms are pinned to
        # (TestSurrogateMeshTerms.test_replicas_win_under_queue_pressure)
        assert steps["rep2_paged"] < base
        assert steps["rep8_paged"] <= steps["rep2_paged"]
        assert steps["grid22_sjf"] < base  # data=2 widens here too
        assert out["preemptions"] > 0, "starved pool never preempted"

    def test_trace_cache_keyed_per_mesh_subprocess(self, tmp_path):
        """Two engines over one shared Model whose meshes produce
        identical avals ((2,1) and (2,2) both widen slots x2) must not
        exchange jaxprs: without per-engine trace keying the second
        dispatch dies on 'incompatible devices' because its inherited
        sharding constraints pin the first engine's device set."""
        assert _run_sub(_TRACEKEY, tmp_path) == {"ok": True}

    def test_sharded_retune_subprocess(self, tmp_path):
        """PR 8's online retuner composing with an active mesh: the
        drift fires, the knob swap stays token-invariant, and the
        winner persists under THIS topology's mesh key."""
        out = _run_sub(_RETUNE, tmp_path)
        assert out["retunes"] == 1
        assert out["applied"]
        assert out["serve_keys"], "retune winner never persisted"
        assert all(k.split("|")[-1] == "d1m2" for k in out["serve_keys"])
