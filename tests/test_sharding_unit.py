"""Unit tests for the logical-axis sharding layer + HLO cost analyzer."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    DEFAULT_RULES,
    DP_ALL_RULES,
    RULE_PRESETS,
    AxisRules,
    axis_rules,
    constrain,
    spec_for_shape,
)


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by spec_for_shape."""

    def __init__(self, **axes):
        self.shape = dict(axes)


class TestSpecForShape:
    def test_basic_mapping(self):
        mesh = FakeMesh(data=16, model=16)
        spec = spec_for_shape((256, 4096), ("batch", "seq"), DEFAULT_RULES,
                              mesh)
        assert spec == P(("data",))  # pod dropped (absent), seq unsharded

    def test_divisibility_fallback(self):
        mesh = FakeMesh(data=16, model=16)
        # 40 heads % 16 != 0 -> heads mapping dropped
        spec = spec_for_shape((5120, 40, 128), ("embed_fsdp", "heads",
                                                "head_dim"),
                              DEFAULT_RULES, mesh)
        assert spec == P("data")

    def test_axis_used_once(self):
        mesh = FakeMesh(data=16, model=16)
        spec = spec_for_shape((64, 64), ("ff", "vocab"), DEFAULT_RULES, mesh)
        # both want "model"; first dim wins
        assert spec == P("model")

    def test_multi_axis_batch(self):
        mesh = FakeMesh(pod=2, data=16, model=16)
        spec = spec_for_shape((512, 10), ("batch", None), DP_ALL_RULES, mesh)
        assert spec == P(("pod", "data", "model"))

    def test_missing_mesh_axis_dropped(self):
        mesh = FakeMesh(data=4)
        spec = spec_for_shape((8, 8), ("batch", "ff"), DEFAULT_RULES, mesh)
        assert spec == P("data")  # pod and model axes absent

    def test_rules_replace(self):
        r = DEFAULT_RULES.replace(seq="model", brand_new="data")
        assert r.lookup("seq") == "model"
        assert r.lookup("brand_new") == "data"
        assert DEFAULT_RULES.lookup("seq") is None  # immutable

    def test_presets_exist(self):
        for name in ("dp", "dp_all", "fsdp_all", "tp", "fsdp_tp"):
            assert name in RULE_PRESETS

    def test_constrain_noop_without_rules(self):
        x = jnp.ones((4, 4))
        y = constrain(x, "batch", "embed")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestServePresets:
    """Property tests for the serve_tp / serve_replicas presets the
    sharded engine activates (PR 9): what each layout actually pins."""

    SHAPES = [
        ((8, 64, 16), ("batch", "heads", "head_dim")),
        ((8, 128, 4, 16), ("batch", "seq", "kv_heads", "head_dim")),
        ((64, 256), ("embed", "ff")),
        ((64, 512), ("embed", "vocab")),
    ]

    def test_presets_registered(self):
        assert "serve_tp" in RULE_PRESETS
        assert "serve_replicas" in RULE_PRESETS

    def test_tp_splits_model_axes_only(self):
        mesh = FakeMesh(data=1, model=4)
        rules = RULE_PRESETS["serve_tp"]
        for shape, axes in self.SHAPES:
            spec = spec_for_shape(shape, axes, rules, mesh)
            # size-1 data axis drops: batch never shards on pure TP
            assert "data" not in [s for e in spec for s in
                                  ([e] if isinstance(e, str) else e or [])]
        assert spec_for_shape((8, 64, 16),
                              ("batch", "heads", "head_dim"),
                              rules, mesh) == P(None, "model")
        assert spec_for_shape((64, 256), ("embed", "ff"),
                              rules, mesh) == P(None, "model")

    def test_replicas_shard_batch_only(self):
        mesh = FakeMesh(data=4, model=1)
        rules = RULE_PRESETS["serve_replicas"]
        for shape, axes in self.SHAPES:
            spec = spec_for_shape(shape, axes, rules, mesh)
            flat = [s for e in spec for s in
                    ([e] if isinstance(e, str) else e or [])]
            assert "model" not in flat
            assert ("data" in flat) == ("batch" in axes)

    def test_tp_degenerates_to_replicas_on_data_mesh(self):
        """serve_tp on a (K, 1) mesh IS serve_replicas: the size-1 model
        axis drops from every rule, leaving only batch -> data.  This is
        why the engine can default to serve_tp for both layouts."""
        mesh = FakeMesh(data=4, model=1)
        for shape, axes in self.SHAPES:
            assert spec_for_shape(shape, axes,
                                  RULE_PRESETS["serve_tp"], mesh) \
                == spec_for_shape(shape, axes,
                                  RULE_PRESETS["serve_replicas"], mesh)

    def test_non_dividing_dim_replicates(self):
        mesh = FakeMesh(data=1, model=8)
        # 12 heads % 8 != 0 -> the dim replicates rather than erroring
        spec = spec_for_shape((4, 12, 16), ("batch", "heads", "head_dim"),
                              RULE_PRESETS["serve_tp"], mesh)
        assert spec == P()

    def test_pool_axes_shard_kv_heads_only(self):
        """The paged pool's declared layout: page-group axis whole (the
        scalar-prefetched page table indexes it), kv_heads split."""
        from repro.kernels.paged_attention import POOL_AXES
        mesh = FakeMesh(data=1, model=2)
        spec = spec_for_shape((8, 64, 4, 16), POOL_AXES,
                              RULE_PRESETS["serve_tp"], mesh)
        assert spec == P(None, None, "model")


class TestHloCostAnalyzer:
    def test_scan_trip_count(self):
        from repro.utils.hlo_cost import analyze_hlo

        def body(x, w):
            return x @ w, ()

        def f(x, ws):
            return jax.lax.scan(body, x, ws)[0]

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
        c = jax.jit(f).lower(x, ws).compile()
        a = analyze_hlo(c.as_text())
        assert a.flops == pytest.approx(7 * 2 * 64**3, rel=0.01)
        assert not a.unresolved_trips

    def test_nested_scan(self):
        from repro.utils.hlo_cost import analyze_hlo

        def f(x, ws):
            def outer(xx, w):
                def inner(y, _):
                    return y @ w, ()
                return jax.lax.scan(inner, xx, None, length=5)[0], ()
            return jax.lax.scan(outer, x, ws)[0]

        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((3, 32, 32), jnp.float32)
        c = jax.jit(f).lower(x, ws).compile()
        a = analyze_hlo(c.as_text())
        assert a.flops == pytest.approx(15 * 2 * 32**3, rel=0.01)

    def test_collectives_counted_with_trips(self):
        """psum inside a scan must be multiplied by the trip count —
        runs in a subprocess with 8 host devices."""
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            import sys
            sys.path.insert(0, "src")
            from repro.utils.hlo_cost import analyze_hlo

            mesh = jax.make_mesh((8,), ("d",))
            def body(c, x):
                return c + (x @ x).sum(), ()
            def f(xs):
                return jax.lax.scan(body, jnp.float32(0), xs)[0]
            xs = jax.ShapeDtypeStruct((6, 8, 128, 128), jnp.float32)
            sh = NamedSharding(mesh, P(None, "d"))
            comp = jax.jit(f, in_shardings=sh).lower(xs).compile()
            a = analyze_hlo(comp.as_text())
            ar = a.collectives.get("all-reduce", {"count": 0})
            assert ar["count"] >= 6, a.collectives  # one per scan step
            print("OK", a.collectives)
        """)
        out = subprocess.run([sys.executable, "-c", script], cwd="/root/repo",
                             capture_output=True, text=True, timeout=240)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout


class TestDryRunSmoke:
    """End-to-end dry-run of one real cell on the production mesh (512
    placeholder devices) in a subprocess."""

    def test_one_cell_compiles(self):
        script = textwrap.dedent("""
            import sys
            sys.path.insert(0, "src")
            from repro.launch.dryrun import run_cell  # sets XLA_FLAGS first
            rec = run_cell("seamless-m4t-medium", "train_4k",
                           multi_pod=False, verbose=False)
            assert rec["status"] == "ok", rec
            assert rec["n_chips"] == 256
            assert rec["flops_per_device"] > 0
            assert rec["collective_bytes_per_device"] > 0
            assert not rec["unresolved_trips"]
            rec2 = run_cell("seamless-m4t-medium", "decode_32k",
                            multi_pod=True, verbose=False)
            assert rec2["status"] == "ok" and rec2["n_chips"] == 512
            print("OK")
        """)
        out = subprocess.run([sys.executable, "-c", script], cwd="/root/repo",
                             capture_output=True, text=True, timeout=560)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "OK" in out.stdout


class TestTuneCLI:
    """The ACTS-over-the-runtime launcher: probe mode end to end."""

    def test_probe_mode(self):
        script_out = subprocess.run(
            [sys.executable, "-m", "repro.launch.tune",
             "--arch", "seamless-m4t-medium", "--shape", "decode_32k",
             "--probe", "kv_seq_shard=true"],
            cwd="/root/repo", capture_output=True, text=True, timeout=560,
            env={**__import__("os").environ, "PYTHONPATH": "src"})
        assert script_out.returncode == 0, script_out.stderr[-2000:]
        import json as _json

        # the verbose [sut_jax] line also contains braces; the JSON report
        # starts at the first line that is exactly "{"
        txt = script_out.stdout
        blob = _json.loads(txt[txt.index("\n{") + 1:])
        assert blob["arch"] == "seamless-m4t-medium"
        assert blob["config"]["kv_seq_shard"] is True
        assert blob["value_s"] > 0
        assert blob["metrics"]["dominant"] in ("compute", "memory",
                                               "collective")
