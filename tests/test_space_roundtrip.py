"""Property-based round-trips over randomly composed parameter spaces.

The unit-hypercube contract every sampler/optimizer relies on, checked for
arbitrary compositions (mixed parameter kinds, frozen views, composite
prefixing) instead of the hand-picked spaces the unit tests use:

* every config emitted from unit samples validates (stays in-domain),
* ``to_unit_vector`` → ``from_unit_matrix`` is **idempotent**: one trip
  through the cube canonicalizes a config, a second trip is exact,
* the vectorized matrix path agrees with the scalar vector path row by row.

Runs on the real ``hypothesis`` when installed, else the deterministic
stub in ``tests/_hypothesis_stub.py`` (installed by conftest).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BoolParam,
    CompositeSpace,
    EnumParam,
    FloatParam,
    IntParam,
    ParameterSpace,
)

MAX_EXAMPLES = 25


def _random_param(rng, name):
    kind = rng.integers(6)
    if kind == 0:
        return BoolParam(name, default=bool(rng.integers(2)))
    if kind == 1:
        n = int(rng.integers(2, 7))
        choices = tuple(f"c{i}" for i in range(n))
        return EnumParam(name, choices, choices[int(rng.integers(n))])
    if kind == 2:
        lo = int(rng.integers(-8, 8))
        hi = lo + int(rng.integers(1, 100))
        return IntParam(name, lo, hi, default=int(rng.integers(lo, hi + 1)))
    if kind == 3:  # log-scale int (wide buffer-size-style range)
        lo = int(rng.integers(1, 4))
        hi = lo * int(rng.integers(2, 4096))
        return IntParam(name, lo, hi, default=lo, log=True)
    if kind == 4:
        lo = float(rng.uniform(-10, 10))
        hi = lo + float(rng.uniform(0.1, 100))
        return FloatParam(name, lo, hi, default=lo)
    lo = float(rng.uniform(1e-4, 1.0))
    hi = lo * float(rng.uniform(10, 1e4))
    return FloatParam(name, lo, hi, default=lo, log=True)


def _random_space(rng, max_dim=6):
    params = [_random_param(rng, f"p{i}")
              for i in range(int(rng.integers(1, max_dim + 1)))]
    space = ParameterSpace(params)
    if rng.random() < 0.3 and space.dim > 1:
        # freeze a random knob: the view must keep the contract too
        victim = params[int(rng.integers(len(params)))]
        space = space.freeze({victim.name: victim.default})
    return space


def _random_composite(rng):
    n = int(rng.integers(1, 4))
    return CompositeSpace(
        {f"sys{i}": _random_space(rng) for i in range(n)})


def _configs_equal(space, a, b):
    for p in space:
        va, vb = a[p.name], b[p.name]
        if isinstance(p, FloatParam) or isinstance(va, float):
            assert np.isclose(float(va), float(vb), rtol=1e-6, atol=1e-12), \
                f"{p.name}: {va} != {vb}"
        else:
            assert va == vb, f"{p.name}: {va!r} != {vb!r}"


def _check_roundtrip(space, rng):
    m = 8
    units = rng.random((m, space.dim))
    configs = space.from_unit_matrix(units)
    assert len(configs) == m
    for i, cfg in enumerate(configs):
        space.validate(cfg)  # in-domain
        # matrix path == scalar path, row by row (floats may differ in the
        # last ulp between the vectorized and scalar arithmetic)
        _configs_equal(space, space.from_unit_vector(units[i]), cfg)
    # one trip canonicalizes; the second trip is exact (idempotence)
    back = np.stack([space.to_unit_vector(c) for c in configs]) \
        if space.dim else np.zeros((m, 0))
    again = space.from_unit_matrix(back)
    for cfg, cfg2 in zip(configs, again):
        _configs_equal(space, cfg, cfg2)


class TestParameterSpaceRoundTrip:
    @settings(max_examples=MAX_EXAMPLES, deadline=None)
    @given(st.integers(min_value=0, max_value=10**6))
    def test_random_space_roundtrips(self, seed):
        rng = np.random.default_rng(seed)
        _check_roundtrip(_random_space(rng), rng)

    @settings(max_examples=MAX_EXAMPLES, deadline=None)
    @given(st.integers(min_value=0, max_value=10**6))
    def test_defaults_canonicalize(self, seed):
        rng = np.random.default_rng(seed)
        space = _random_space(rng)
        cfg = space.default_config()
        space.validate(cfg)
        u = space.to_unit_vector(cfg)
        assert u.shape == (space.dim,)
        assert ((u >= 0) & (u < 1)).all()
        _configs_equal(space, cfg, space.from_unit_vector(u))


class TestCompositeSpaceRoundTrip:
    @settings(max_examples=MAX_EXAMPLES, deadline=None)
    @given(st.integers(min_value=0, max_value=10**6))
    def test_random_composite_roundtrips(self, seed):
        rng = np.random.default_rng(seed)
        _check_roundtrip(_random_composite(rng), rng)

    @settings(max_examples=MAX_EXAMPLES, deadline=None)
    @given(st.integers(min_value=0, max_value=10**6))
    def test_split_join_inverse(self, seed):
        rng = np.random.default_rng(seed)
        space = _random_composite(rng)
        cfg = space.from_unit_vector(rng.random(space.dim))
        parts = space.split(cfg)
        assert set(parts) == set(space.subspace_names)
        for name, sub in parts.items():
            space.subspace(name).validate(sub)
        assert space.join(parts) == cfg
