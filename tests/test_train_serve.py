"""Integration: fault-tolerant training loop + batched serving engine."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ModelConfig, get_config, reduced
from repro.models import Model
from repro.optim import OptimizerConfig
from repro.serve import ServeConfig, ServeEngine
from repro.train import (
    RunKnobs,
    SimulatedFailure,
    TrainLoopConfig,
    train,
)

TINY = ModelConfig(
    name="tiny-lm", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", vocab_pad_multiple=64,
    rope_theta=10_000.0,
)


def _loop(**kw):
    base = dict(
        steps=12, seq_len=32, global_batch=4, log_every=0,
        opt=OptimizerConfig(learning_rate=3e-3, warmup_steps=2,
                            total_steps=50),
        knobs=RunKnobs(rules_preset="dp", remat="none", microbatches=1,
                       loss_chunk=0),
    )
    base.update(kw)
    return TrainLoopConfig(**base)


class TestTrainLoop:
    def test_loss_decreases(self):
        out = train(TINY, _loop(steps=25))
        first = np.mean([h["loss"] for h in out["history"][:5]])
        last = np.mean([h["loss"] for h in out["history"][-5:]])
        assert last < first

    def test_microbatch_equivalence(self):
        """k microbatches must produce (numerically close) identical training."""
        o1 = train(TINY, _loop(steps=5))
        o2 = train(TINY, _loop(steps=5, knobs=RunKnobs(
            rules_preset="dp", remat="none", microbatches=2, loss_chunk=0)))
        l1 = [h["loss"] for h in o1["history"]]
        l2 = [h["loss"] for h in o2["history"]]
        np.testing.assert_allclose(l1, l2, rtol=2e-3, atol=2e-3)

    def test_compression_trains(self):
        out = train(TINY, _loop(steps=20, knobs=RunKnobs(
            rules_preset="dp", remat="none", microbatches=1, loss_chunk=0,
            compression="int8")))
        first = np.mean([h["loss"] for h in out["history"][:5]])
        last = np.mean([h["loss"] for h in out["history"][-5:]])
        assert last < first

    def test_crash_resume_matches_uninterrupted(self, tmp_path):
        """Kill at step 6, resume from the step-5 checkpoint, finish: final
        params must equal an uninterrupted run (deterministic data + ckpt)."""
        straight = train(TINY, _loop(steps=10))

        ckpt = str(tmp_path / "ckpt")
        with pytest.raises(SimulatedFailure):
            train(TINY, _loop(steps=10, ckpt_dir=ckpt, ckpt_every=5,
                              fail_at_step=6))
        resumed = train(TINY, _loop(steps=10, ckpt_dir=ckpt, ckpt_every=5))
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-4, atol=1e-5),
            straight["params"], resumed["params"])

    def test_remat_equivalence(self):
        o1 = train(TINY, _loop(steps=4))
        o2 = train(TINY, _loop(steps=4, knobs=RunKnobs(
            rules_preset="dp", remat="full", microbatches=1, loss_chunk=0)))
        np.testing.assert_allclose(
            [h["loss"] for h in o1["history"]],
            [h["loss"] for h in o2["history"]], rtol=1e-4)


class TestServeEngine:
    @pytest.fixture(scope="class")
    def engine(self):
        model = Model(TINY)
        params = model.init(jax.random.PRNGKey(0))
        return model, params

    def test_greedy_matches_stepwise_forward(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, ServeConfig(max_seq=64,
                                                     batch_slots=2))
        prompts = [[1, 2, 3, 4, 5], [9, 8, 7, 6, 5]]
        res = eng.generate(prompts, max_new_tokens=6)
        assert len(res.tokens) == 2
        assert all(len(t) == 6 for t in res.tokens)
        # oracle: recompute with full forward each step
        for b, prompt in enumerate(prompts):
            seq = list(prompt)
            for _ in range(6):
                batch = {"tokens": jnp.asarray([seq], jnp.int32)}
                hidden, _ = model.forward(params, batch)
                logits = model._logits(params, hidden)[0, -1,
                                                       :TINY.vocab_size]
                seq.append(int(jnp.argmax(logits)))
            assert seq[len(prompt):] == res.tokens[b]

    def test_wave_packing(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, ServeConfig(max_seq=32,
                                                     batch_slots=2))
        res = eng.generate([[1, 2, 3]] * 5, max_new_tokens=3)
        assert len(res.tokens) == 5
        # identical prompts => identical generations
        assert all(t == res.tokens[0] for t in res.tokens)

    def test_eos_early_exit(self, engine):
        model, params = engine
        # discover the first greedy token, then use it as EOS
        eng = ServeEngine(model, params, ServeConfig(max_seq=32,
                                                     batch_slots=1))
        probe = eng.generate([[3, 1, 4]], max_new_tokens=1).tokens[0][0]
        eng_eos = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=1, eos_token=int(probe)))
        res = eng_eos.generate([[3, 1, 4]], max_new_tokens=8)
        assert res.tokens[0] == [probe]
        assert res.steps <= 2

    def test_unequal_prompts_rejected_by_wave(self, engine):
        """The WAVE runtime keeps its equal-length contract; the default
        continuous runtime is exactly what lifts it."""
        model, params = engine
        eng = ServeEngine(model, params, ServeConfig(max_seq=32,
                                                     runtime="wave"))
        with pytest.raises(ValueError, match="equal-length"):
            eng.generate([[1, 2], [1, 2, 3]], max_new_tokens=2)
        cont = ServeEngine(model, params, ServeConfig(max_seq=32))
        res = cont.generate([[1, 2], [1, 2, 3]], max_new_tokens=2)
        assert [len(t) for t in res.tokens] == [2, 2]

    def test_throughput_metrics(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, ServeConfig(max_seq=32,
                                                     batch_slots=4))
        res = eng.generate([[5, 6, 7]] * 4, max_new_tokens=4)
        assert res.decode_tokens_per_sec > 0
        assert res.prefill_seconds > 0


class TestChunkedPrefill:
    """Runtime chunked prefill: splitting the prompt into prefill_chunk
    segments threaded through the KV cache must be value-exact vs
    whole-prompt prefill — the knob moves *timing*, never tokens."""

    # (prompt_len, prefill_chunk): dividing, non-dividing, chunk == prompt,
    # chunk > prompt, and the degenerate one-token chunk
    PAIRS = [(12, 4), (13, 5), (13, 4), (12, 12), (5, 64), (9, 1)]

    @pytest.fixture(scope="class")
    def engine(self):
        model = Model(TINY)
        params = model.init(jax.random.PRNGKey(0))
        return model, params

    def _prompts(self, plen, n=2):
        rng = np.random.default_rng(plen)
        return rng.integers(1, TINY.vocab_size, size=(n, plen)).tolist()

    @pytest.mark.parametrize("plen,chunk", PAIRS)
    def test_token_parity(self, engine, plen, chunk):
        model, params = engine
        assert model.supports_chunked_prefill
        prompts = self._prompts(plen)
        # wave runtime: the historical whole-wave chunk-count contract
        # (the continuous runtime prefills per slot; see
        # tests/test_continuous_batching.py for its parity pins)
        whole = ServeEngine(model, params, ServeConfig(
            max_seq=64, batch_slots=2, prefill_chunk=2048, runtime="wave"))
        chunked = ServeEngine(model, params, ServeConfig(
            max_seq=64, batch_slots=2, prefill_chunk=chunk, runtime="wave"))
        rw = whole.generate(prompts, max_new_tokens=6)
        rc = chunked.generate(prompts, max_new_tokens=6)
        assert rc.tokens == rw.tokens  # byte-identical continuations
        expect = math.ceil(plen / chunk) if chunk < plen else 1
        assert rc.prefill_chunks == expect  # the knob demonstrably acts
        assert rw.prefill_chunks == 1

    @pytest.mark.parametrize("plen,chunk", PAIRS)
    def test_kv_cache_parity(self, engine, plen, chunk):
        """Chunked and whole-prompt prefill leave identical KV caches and
        last-token logits behind."""
        model, params = engine
        tok = jnp.asarray(self._prompts(plen), jnp.int32)
        lg_w, cache_w = model.prefill(params, {"tokens": tok},
                                      model.init_cache(2, max_seq=64))
        cache_c = model.init_cache(2, max_seq=64)
        for s in range(0, plen, chunk):
            lg_c, cache_c = model.prefill_chunk(
                params, {"tokens": tok[:, s:s + chunk]}, cache_c)
        assert int(cache_w["index"]) == int(cache_c["index"]) == plen
        np.testing.assert_allclose(np.asarray(lg_w), np.asarray(lg_c),
                                   rtol=1e-5, atol=1e-6)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
            cache_w["blocks"], cache_c["blocks"])

    def test_decode_continues_from_chunked_cache(self, engine):
        """Greedy decode from a chunk-built cache matches the stepwise
        full-forward oracle (chunking is invisible downstream)."""
        model, params = engine
        prompt = [3, 1, 4, 1, 5, 9, 2]
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=1, prefill_chunk=3))
        res = eng.generate([prompt], max_new_tokens=4)
        seq = list(prompt)
        for _ in range(4):
            batch = {"tokens": jnp.asarray([seq], jnp.int32)}
            hidden, _ = model.forward(params, batch)
            logits = model._logits(params, hidden)[0, -1, :TINY.vocab_size]
            seq.append(int(jnp.argmax(logits)))
        assert seq[len(prompt):] == res.tokens[0]

    def test_live_serve_sut_measures_real_engine(self):
        """LiveServeSUT: a test builds the real engine under the candidate
        knobs and wall-clocks it — metrics carry the chunk count, so a
        tuned prefill_chunk is visible in the provenance."""
        from repro.serve.space import LiveServeSUT

        model = Model(TINY)
        params = model.init(jax.random.PRNGKey(0))
        sut = LiveServeSUT(model, params,
                           base=ServeConfig(max_seq=32),
                           prompt_len=9, gen_len=4, n_requests=2,
                           warmup=1, repeats=1, max_slots=2)
        space = sut.space()
        cfg = space.default_config()
        cfg["prefill_chunk"] = 4  # non-dividing: 9 tokens -> 3 chunks
        cfg["max_batch"] = 2
        m = sut.test(cfg)
        assert m.higher_is_better and m.value > 0
        assert m.metrics["latency_s"] > 0
        # continuous runtime: per-request prefill => 2 requests x 3 chunks
        assert m.metrics["prefill_chunks"] == 6
        assert m.metrics["prefill_s"] > 0

    def test_train_step_sut_measures_real_step(self):
        """TrainStepSUT: re-jits the real train step under the knobs and
        wall-clocks the microbatch loop (median-of-repeats timing)."""
        from repro.core.sut_jax import TrainStepSUT

        sut = TrainStepSUT(TINY, seq_len=16, global_batch=4, steps=1,
                           warmup=1, repeats=1)
        space = sut.space()
        cfg = space.default_config()
        cfg["microbatches"] = 2
        m = sut.test(cfg)
        assert m.higher_is_better and m.value > 0
        assert m.metrics["step_seconds"] > 0
        assert np.isfinite(m.metrics["loss"])

    def test_unsupported_stack_falls_back_to_whole_prefill(self):
        """Models whose blocks cannot append multi-token segments exactly
        (recurrent mixers) prefill whole prompts regardless of the knob."""
        cfg = reduced(get_config("zamba2-1.2b"))
        model = Model(cfg)
        assert not model.supports_chunked_prefill
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=1, prefill_chunk=2))
        res = eng.generate([[1, 2, 3, 4, 5]], max_new_tokens=2)
        assert res.prefill_chunks == 1  # one whole-prompt dispatch
        assert len(res.tokens[0]) == 2

    def test_frontend_model_chunked_parity_and_validation(self):
        """Frontend/encoder models: generate() without embeds fails loudly
        on BOTH prefill paths (the chunked path would otherwise silently
        attend to zero memory), and with embeds the first chunk carries
        them so chunked == whole-prompt tokens."""
        cfg = reduced(get_config("llama-3.2-vision-90b"))
        model = Model(cfg)
        assert model.supports_chunked_prefill
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = rng.integers(1, cfg.vocab_size, size=(2, 9)).tolist()
        fe = rng.normal(size=(2, cfg.frontend_tokens,
                              cfg.frontend_dim)).astype(np.float32)
        chunked = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=2, prefill_chunk=4, runtime="wave"))
        with pytest.raises(ValueError, match="frontend"):
            chunked.generate(prompts, max_new_tokens=2)
        whole = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=2, prefill_chunk=2048, runtime="wave"))
        with pytest.raises(ValueError, match="frontend"):
            whole.generate(prompts, max_new_tokens=2)
        rw = whole.generate(prompts, max_new_tokens=3, frontend_embeds=fe)
        rc = chunked.generate(prompts, max_new_tokens=3,
                              frontend_embeds=fe)
        assert rc.tokens == rw.tokens
        assert rc.prefill_chunks == 3  # ceil(9 / 4)

    def test_capacity_bound_moe_is_not_chunkable(self):
        """Capacity-bound MoE routing drops tokens per routing GROUP, and
        the grouping differs between whole-prompt and per-chunk prefill —
        chunking such a stack would change generated tokens, so the gate
        must refuse it.  Drop-free capacity (cf*K >= E, what ``reduced``
        configs use) keeps MoE chunk-exact and allowed."""
        base = reduced(get_config("grok-1-314b"))
        assert base.moe is not None
        # reduced() picks drop-free capacity: chunking is exact -> allowed
        assert (base.moe.capacity_factor * base.moe.experts_per_token
                >= base.moe.n_experts)
        assert Model(base).supports_chunked_prefill
        # a production-style capacity factor (tokens get dropped) -> gated
        bound = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, capacity_factor=1.0))
        assert not Model(bound).supports_chunked_prefill
