"""Integration: fault-tolerant training loop + batched serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ModelConfig, get_config, reduced
from repro.models import Model
from repro.optim import OptimizerConfig
from repro.serve import ServeConfig, ServeEngine
from repro.train import (
    RunKnobs,
    SimulatedFailure,
    TrainLoopConfig,
    train,
)

TINY = ModelConfig(
    name="tiny-lm", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", vocab_pad_multiple=64,
    rope_theta=10_000.0,
)


def _loop(**kw):
    base = dict(
        steps=12, seq_len=32, global_batch=4, log_every=0,
        opt=OptimizerConfig(learning_rate=3e-3, warmup_steps=2,
                            total_steps=50),
        knobs=RunKnobs(rules_preset="dp", remat="none", microbatches=1,
                       loss_chunk=0),
    )
    base.update(kw)
    return TrainLoopConfig(**base)


class TestTrainLoop:
    def test_loss_decreases(self):
        out = train(TINY, _loop(steps=25))
        first = np.mean([h["loss"] for h in out["history"][:5]])
        last = np.mean([h["loss"] for h in out["history"][-5:]])
        assert last < first

    def test_microbatch_equivalence(self):
        """k microbatches must produce (numerically close) identical training."""
        o1 = train(TINY, _loop(steps=5))
        o2 = train(TINY, _loop(steps=5, knobs=RunKnobs(
            rules_preset="dp", remat="none", microbatches=2, loss_chunk=0)))
        l1 = [h["loss"] for h in o1["history"]]
        l2 = [h["loss"] for h in o2["history"]]
        np.testing.assert_allclose(l1, l2, rtol=2e-3, atol=2e-3)

    def test_compression_trains(self):
        out = train(TINY, _loop(steps=20, knobs=RunKnobs(
            rules_preset="dp", remat="none", microbatches=1, loss_chunk=0,
            compression="int8")))
        first = np.mean([h["loss"] for h in out["history"][:5]])
        last = np.mean([h["loss"] for h in out["history"][-5:]])
        assert last < first

    def test_crash_resume_matches_uninterrupted(self, tmp_path):
        """Kill at step 6, resume from the step-5 checkpoint, finish: final
        params must equal an uninterrupted run (deterministic data + ckpt)."""
        straight = train(TINY, _loop(steps=10))

        ckpt = str(tmp_path / "ckpt")
        with pytest.raises(SimulatedFailure):
            train(TINY, _loop(steps=10, ckpt_dir=ckpt, ckpt_every=5,
                              fail_at_step=6))
        resumed = train(TINY, _loop(steps=10, ckpt_dir=ckpt, ckpt_every=5))
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-4, atol=1e-5),
            straight["params"], resumed["params"])

    def test_remat_equivalence(self):
        o1 = train(TINY, _loop(steps=4))
        o2 = train(TINY, _loop(steps=4, knobs=RunKnobs(
            rules_preset="dp", remat="full", microbatches=1, loss_chunk=0)))
        np.testing.assert_allclose(
            [h["loss"] for h in o1["history"]],
            [h["loss"] for h in o2["history"]], rtol=1e-4)


class TestServeEngine:
    @pytest.fixture(scope="class")
    def engine(self):
        model = Model(TINY)
        params = model.init(jax.random.PRNGKey(0))
        return model, params

    def test_greedy_matches_stepwise_forward(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, ServeConfig(max_seq=64,
                                                     batch_slots=2))
        prompts = [[1, 2, 3, 4, 5], [9, 8, 7, 6, 5]]
        res = eng.generate(prompts, max_new_tokens=6)
        assert len(res.tokens) == 2
        assert all(len(t) == 6 for t in res.tokens)
        # oracle: recompute with full forward each step
        for b, prompt in enumerate(prompts):
            seq = list(prompt)
            for _ in range(6):
                batch = {"tokens": jnp.asarray([seq], jnp.int32)}
                hidden, _ = model.forward(params, batch)
                logits = model._logits(params, hidden)[0, -1,
                                                       :TINY.vocab_size]
                seq.append(int(jnp.argmax(logits)))
            assert seq[len(prompt):] == res.tokens[b]

    def test_wave_packing(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, ServeConfig(max_seq=32,
                                                     batch_slots=2))
        res = eng.generate([[1, 2, 3]] * 5, max_new_tokens=3)
        assert len(res.tokens) == 5
        # identical prompts => identical generations
        assert all(t == res.tokens[0] for t in res.tokens)

    def test_eos_early_exit(self, engine):
        model, params = engine
        # discover the first greedy token, then use it as EOS
        eng = ServeEngine(model, params, ServeConfig(max_seq=32,
                                                     batch_slots=1))
        probe = eng.generate([[3, 1, 4]], max_new_tokens=1).tokens[0][0]
        eng_eos = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=1, eos_token=int(probe)))
        res = eng_eos.generate([[3, 1, 4]], max_new_tokens=8)
        assert res.tokens[0] == [probe]
        assert res.steps <= 2

    def test_unequal_prompts_rejected(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, ServeConfig(max_seq=32))
        with pytest.raises(ValueError):
            eng.generate([[1, 2], [1, 2, 3]], max_new_tokens=2)

    def test_throughput_metrics(self, engine):
        model, params = engine
        eng = ServeEngine(model, params, ServeConfig(max_seq=32,
                                                     batch_slots=4))
        res = eng.generate([[5, 6, 7]] * 4, max_new_tokens=4)
        assert res.decode_tokens_per_sec > 0
        assert res.prefill_seconds > 0
