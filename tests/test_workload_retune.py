"""Online workload-aware retuning: fingerprints, shift detection, warm
transfer, and the mid-stream knob swap.

The contract under test (ROADMAP direction 2, PR 8):

* the workload fingerprint is measured, deterministic and step-counted —
  the same request trace produces the same fingerprint, signature and
  retune trigger step, every run;
* ``nearest_workload`` transfers cached winners across *similar* (not
  just identical) workload signatures, and a warm-started retune is
  never worse than a cold restart at the same test budget;
* the engine's mid-run knob swap moves scheduling/batching/speculation
  knobs only — generated tokens stay bit-identical across the swap
  (sampling keys on (rid, token-index), nothing else);
* ``GenerationResult.acceptance_rate`` distinguishes "no drafts ran"
  (nan) from "every draft was rejected" (0.0) — the bugfix that lets
  measured acceptance feed ``CotuneParams.spec_accept`` safely.
"""
import math

import numpy as np
import pytest

from repro.core.tuner import Tuner
from repro.serve.space import (CotuneParams, ServeSurrogate,
                               params_for_fingerprint, serve_knob_space)
from repro.serve.workload import (OnlineRetuner, WorkloadFingerprint,
                                  WorkloadWindow, coerce_config,
                                  fingerprint_distance, fingerprint_sig,
                                  nearest_workload, parse_sig)

FP = WorkloadFingerprint(arrival_rate=0.5, prompt_mean=24.0,
                         prompt_spread=0.35, gen_mean=8.0, depth=12.0,
                         share_frac=0.30, accept_rate=0.60)


class TestSignature:
    def test_round_trip(self):
        assert fingerprint_distance(FP, parse_sig(fingerprint_sig(FP))) \
            < 1e-9

    def test_canonical_form(self):
        assert fingerprint_sig(FP) == "a0.50_d12_g8_p24_r0.35_s0.30_x0.60"

    def test_nan_acceptance_round_trips(self):
        fp = WorkloadFingerprint(0.5, 24.0, 0.35, 8.0, 12.0, 0.30,
                                 float("nan"))
        sig = fingerprint_sig(fp)
        assert sig.endswith("x?")
        back = parse_sig(sig)
        assert math.isnan(back.accept_rate)

    @pytest.mark.parametrize("junk", ["-", "", "v3|serve|x", "a0.5",
                                      "a0.50_d12_g8_p24_r0.35_s0.30",
                                      "z1_y2_x3_w4_v5_u6_t7"])
    def test_non_signatures_parse_to_none(self, junk):
        assert parse_sig(junk) is None

    def test_distance_identity_and_symmetry(self):
        other = WorkloadFingerprint(1.0, 30.0, 0.10, 6.0, 4.0, 0.80, 0.20)
        assert fingerprint_distance(FP, FP) == 0.0
        assert fingerprint_distance(FP, other) == \
            fingerprint_distance(other, FP)
        assert fingerprint_distance(FP, other) > 0.0

    def test_missing_acceptance_is_not_a_shift(self):
        """nan on either side drops the acceptance component instead of
        reading 'no draft data yet' as workload drift."""
        nodata = WorkloadFingerprint(0.5, 24.0, 0.35, 8.0, 12.0, 0.30,
                                     float("nan"))
        assert fingerprint_distance(FP, nodata) == 0.0


class TestNearestWorkload:
    def _entry(self, tag):
        return {"config": {"max_batch": 4}, "value": 1.0, "meta": {"t": tag}}

    def test_nearest_parseable_wins(self):
        near = fingerprint_sig(WorkloadFingerprint(
            0.55, 24.0, 0.35, 8.0, 12.0, 0.30, 0.60))
        far = fingerprint_sig(WorkloadFingerprint(
            2.0, 4.0, 0.0, 30.0, 1.0, 0.0, 0.0))
        cands = {near: self._entry("near"), far: self._entry("far"),
                 "-": self._entry("generic")}
        ws, entry, d = nearest_workload(cands, FP, radius=0.75)
        assert ws == near and entry["meta"]["t"] == "near"
        assert d < 0.1

    def test_generic_entry_is_the_fallback_at_radius(self):
        """The offline winner's '-' signature sits AT the radius: used
        when nothing parseable is nearer, beaten by anything that is."""
        got = nearest_workload({"-": self._entry("generic")}, FP,
                               radius=0.75)
        assert got is not None
        ws, _, d = got
        assert ws == "-" and d == 0.75

    def test_beyond_radius_returns_none(self):
        far = fingerprint_sig(WorkloadFingerprint(
            2.0, 4.0, 0.0, 30.0, 1.0, 0.0, 0.0))
        assert nearest_workload({far: self._entry("far")}, FP,
                                radius=0.3) is None

    def test_empty_candidates(self):
        assert nearest_workload({}, FP, radius=0.75) is None


class TestCoerceConfig:
    def test_out_of_space_values_snap(self):
        """A deployed 512-token prefill_chunk must seed a 48-token
        window's space as its largest valid choice, not explode."""
        space = serve_knob_space(48, max_slots=8)
        cfg = coerce_config(space, {"max_batch": 64, "prefill_chunk": 512,
                                    "kv_cache_pages": 9999,
                                    "schedule": "sjf",
                                    "page_policy": "on_demand",
                                    "share_prefix": 1, "draft_len": 4,
                                    "bogus_knob": 7})
        space.validate(cfg)  # raises if coercion failed
        assert "bogus_knob" not in cfg
        assert cfg["max_batch"] == 8
        assert cfg["schedule"] == "sjf" and cfg["draft_len"] == 4

    def test_invalid_enum_falls_to_default(self):
        space = serve_knob_space(48, max_slots=8)
        cfg = coerce_config(space, {"schedule": "not-a-policy"})
        assert cfg["schedule"] == space["schedule"].default

    def test_frozen_values_override(self):
        space = serve_knob_space(48, max_slots=8).freeze(
            {"kv_cache_pages": 12})
        cfg = coerce_config(space, {"kv_cache_pages": 24})
        assert cfg["kv_cache_pages"] == 12
        space.validate(cfg)


class TestWorkloadWindow:
    def test_fingerprint_measures_the_trace(self):
        w = WorkloadWindow(capacity=8)
        for i in range(4):
            w.record_request(step=i * 2, prompt=[1] * 20, max_new=10)
        w.record_depth(3)
        w.record_depth(5)
        fp = w.fingerprint(step=7)
        assert fp.prompt_mean == 20 and fp.gen_mean == 10
        assert fp.arrival_rate == pytest.approx(4 / 8)
        assert fp.depth == pytest.approx(4.0)
        assert fp.prompt_spread == 0.0
        # identical prompts: after the first, fully covered by the window
        assert fp.share_frac > 0.5

    def test_distinct_prompts_share_nothing(self):
        rng = np.random.default_rng(0)
        w = WorkloadWindow(capacity=8)
        for i in range(5):
            w.record_request(i, rng.integers(1, 500, size=16).tolist(), 4)
        assert w.fingerprint(step=5).share_frac < 0.2

    def test_acceptance_nan_until_drafts(self):
        w = WorkloadWindow()
        w.record_request(0, [1, 2, 3], 4)
        assert math.isnan(w.fingerprint(0).accept_rate)
        w.record_draft(4, 3)
        assert w.fingerprint(0).accept_rate == pytest.approx(0.75)
        w.record_draft(0, 0)  # no proposal: must not dilute the rate
        assert w.fingerprint(0).accept_rate == pytest.approx(0.75)

    def test_empty_window_has_no_fingerprint(self):
        assert WorkloadWindow().fingerprint(0) is None

    def test_window_slides(self):
        w = WorkloadWindow(capacity=2)
        w.record_request(0, [1] * 30, 2)
        w.record_request(1, [1] * 6, 2)
        w.record_request(2, [1] * 6, 2)
        assert w.n_requests == 2
        assert w.fingerprint(2).prompt_mean == 6.0


def _retuner(optimizer="rrs", seed=0, batch=None, **kw):
    space = serve_knob_space(48, max_slots=8)
    params = CotuneParams(max_seq=48, prompt_len=24, gen_len=12)
    defaults = dict(budget=8, threshold=0.25, min_requests=4, cooldown=8,
                    check_every=2, optimizer=optimizer, seed=seed,
                    batch=batch)
    defaults.update(kw)
    return OnlineRetuner(space, params, **defaults)


def _drive(rt, *, shift_at=20, n_steps=40, trace_seed=7):
    """A synthetic serve trace: steady long prompts, then a shift to
    short shared-prefix bursts at ``shift_at``.  Returns the events."""
    rng = np.random.default_rng(trace_seed)
    w = WorkloadWindow(capacity=8)
    shared = rng.integers(1, 500, size=20).tolist()
    events = []
    for step in range(n_steps):
        if step % 4 == 0:
            if step < shift_at:
                w.record_request(step,
                                 rng.integers(1, 500, size=24).tolist(), 12)
            else:
                for _ in range(3):  # burstier, short, shared
                    w.record_request(
                        step, shared + rng.integers(1, 500, size=2).tolist(),
                        3)
        w.record_depth(2 if step < shift_at else 8)
        hit = rt.maybe_retune(w, step)
        if hit is not None:
            events.append(hit)
    return events


class TestShiftDetection:
    def test_anchors_then_fires_once(self):
        rt = _retuner(cooldown=1000)
        events = _drive(rt)
        assert len(events) == 1
        assert events[0]["step"] >= 20  # never before the actual shift
        assert events[0]["distance"] > rt.threshold

    def test_no_shift_no_retune(self):
        rt = _retuner()
        events = _drive(rt, shift_at=10 ** 9)
        assert events == [] and rt.n_retunes == 0

    def test_cooldown_bounds_retune_rate(self):
        eager = _retuner(threshold=0.05, cooldown=4)
        lazy = _retuner(threshold=0.05, cooldown=1000)
        n_eager = len(_drive(eager, n_steps=60))
        n_lazy = len(_drive(lazy, n_steps=60))
        assert n_lazy == 1 and n_eager >= 1

    def test_min_requests_gates_the_fingerprint(self):
        rt = _retuner(min_requests=10 ** 6, cooldown=1000)
        assert _drive(rt) == []

    def test_measured_acceptance_feeds_spec_accept(self):
        """The tentpole's point: the retune's surrogate params carry the
        MEASURED acceptance rate, not the 0.6 default constant."""
        rt = _retuner(cooldown=1000)
        fp = WorkloadFingerprint(0.5, 6.0, 0.1, 3.0, 8.0, 0.9, 0.85)
        ev = rt.retune(fp, step=0)
        assert ev["spec_accept"] == pytest.approx(0.85)
        assert ev["measured_accept"] == pytest.approx(0.85)
        # and without draft data the default survives (nan never lands)
        params = params_for_fingerprint(
            WorkloadFingerprint(0.5, 6.0, 0.1, 3.0, 8.0, 0.9,
                                float("nan")),
            CotuneParams(max_seq=48))
        assert params.spec_accept == CotuneParams(max_seq=48).spec_accept

    def test_same_trace_same_trigger(self):
        runs = [_drive(_retuner(cooldown=1000)) for _ in range(2)]
        assert [e["step"] for e in runs[0]] == \
            [e["step"] for e in runs[1]]
        assert runs[0][0]["config"] == runs[1][0]["config"]
        assert runs[0][0]["signature"] == runs[1][0]["signature"]


class TestWarmTransfer:
    def _fp_b(self):
        return WorkloadFingerprint(0.75, 22.0, 0.10, 3.0, 8.0, 0.90, 0.85)

    def test_nearest_signature_beats_cold_at_equal_budget(self):
        """The transfer claim: seeding from the nearest cached winner
        reaches an at-least-as-good config as a cold restart spending
        the same test budget."""
        fp_b = self._fp_b()
        params = params_for_fingerprint(fp_b, CotuneParams(max_seq=48))
        space = serve_knob_space(48, max_slots=8)
        # the donor: a well-funded earlier tune at a nearby workload
        donor = Tuner(space, ServeSurrogate(params), budget=64,
                      seed=3).run()
        near_sig = fingerprint_sig(WorkloadFingerprint(
            0.70, 22.0, 0.12, 3.0, 8.0, 0.88, 0.80))
        rt_warm = _retuner(budget=6, cooldown=1000)
        rt_warm._candidates = lambda: {
            near_sig: {"config": dict(donor.best_config),
                       "value": donor.best_metric.value}}
        rt_warm.sig_dims = None  # no cache writes from the unit test
        rt_cold = _retuner(budget=6, cooldown=1000)
        ev_warm = rt_warm.retune(fp_b, step=0)
        ev_cold = rt_cold.retune(fp_b, step=0)
        assert ev_warm["warm_source"].startswith("near(")
        assert ev_cold["warm_source"] == "cold"
        assert ev_warm["n_tests"] == ev_cold["n_tests"] == 6
        # equal budget: warm reaches at least the cold winner's quality
        assert ev_warm["value"] >= ev_cold["value"]
        # ... and at this tiny budget the donor transfer is a strict win
        assert ev_warm["value"] > ev_cold["value"]

    def test_exact_signature_hit_is_labelled(self):
        fp_b = self._fp_b()
        sig = fingerprint_sig(fp_b)
        rt = _retuner(budget=6, cooldown=1000)
        rt._candidates = lambda: {
            sig: {"config": serve_knob_space(48, 8).default_config(),
                  "value": 1.0}}
        assert rt.retune(fp_b, step=0)["warm_source"] == "exact"

    def test_retune_updates_baseline_and_active_config(self):
        rt = _retuner(cooldown=1000)
        fp_b = self._fp_b()
        ev = rt.retune(fp_b, step=5)
        assert rt.baseline == fp_b
        assert rt.active_config == ev["config"]
        assert rt.tests_spent == ev["n_tests"]
        # immediately after, the same fingerprint is no longer a shift
        assert fingerprint_distance(fp_b, rt.baseline) == 0.0


# ---------------------------------------------------------------------------
# engine-level: the mid-stream swap, measured acceptance, bounded drafting
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine_parts():
    import jax

    from repro.configs import ModelConfig
    from repro.models import Model

    cfg = ModelConfig(
        name="tiny-retune", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
        param_dtype="float32", compute_dtype="float32",
        vocab_pad_multiple=64, rope_theta=10_000.0)
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0)), cfg


def _drift_workload(seed=0):
    """Phase A (distinct long prompts, long gens) then phase B (shared
    prefix, short tails, short gens) — the drift the retuner must see."""
    rng = np.random.default_rng(seed)
    pa = [rng.integers(1, 500, size=20).tolist() for _ in range(3)]
    shared = rng.integers(1, 500, size=32).tolist()
    pb = [shared + rng.integers(1, 500, size=3).tolist()
          for _ in range(12)]
    return pa + pb, [12] * 3 + [6] * 12


def _serve(model, params, prompts, max_new, tmp_path, monkeypatch,
           **overrides):
    from repro import autotune
    from repro.serve import ServeConfig, ServeEngine

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "cache.json"))
    autotune.reset_default_cache()
    base = dict(max_seq=48, batch_slots=8, kv_layout="paged", seed=0,
                prefill_chunk=8, slot_cap=3)
    base.update(overrides)
    eng = ServeEngine(model, params, ServeConfig(**base))
    try:
        return eng, eng.generate(prompts, max_new)
    finally:
        autotune.reset_default_cache()


def _phase_a_sig(model, params, tmp_path, monkeypatch):
    """The signature the (stale) offline winner was tuned under: measure
    it from a phase-A-only run with the detector anchored but inert."""
    rng = np.random.default_rng(0)
    pa = [rng.integers(1, 500, size=20).tolist() for _ in range(6)]
    eng, _ = _serve(model, params, pa, [12] * 6, tmp_path, monkeypatch,
                    retune=True, retune_threshold=10.0,
                    retune_min_requests=6, retune_window=10)
    return fingerprint_sig(eng.last_retuner.baseline)


RETUNE_KW = dict(retune=True, retune_budget=8, retune_threshold=0.3,
                 retune_window=10, retune_cooldown=200,
                 retune_check_every=2, retune_min_requests=6)


class TestEngineRetune:
    def test_swap_preserves_tokens_and_fires_once(
            self, tiny_engine_parts, tmp_path, monkeypatch):
        model, params, mcfg = tiny_engine_parts
        sig_a = _phase_a_sig(model, params, tmp_path, monkeypatch)
        prompts, max_new = _drift_workload()
        eng, res = _serve(model, params, prompts, max_new, tmp_path,
                          monkeypatch, tuned_signature=sig_a, **RETUNE_KW)
        _, base = _serve(model, params, prompts, max_new, tmp_path,
                         monkeypatch)
        assert len(res.retunes) == 1
        ev = res.retunes[0]
        assert ev["distance"] > 0.3 and ev["applied"]
        # the swap moved scheduling/batching knobs, never token content
        assert res.tokens == base.tokens
        # the allocator survived the mid-run policy swap balanced
        eng.last_alloc.check_balanced()
        # measured acceptance (the probe ran) reached the surrogate
        assert math.isfinite(ev["measured_accept"])
        assert abs(ev["spec_accept"] - ev["measured_accept"]) <= 0.1

    def test_retune_step_is_deterministic(self, tiny_engine_parts,
                                          tmp_path, monkeypatch):
        model, params, mcfg = tiny_engine_parts
        sig_a = _phase_a_sig(model, params, tmp_path, monkeypatch)
        prompts, max_new = _drift_workload()
        runs = [_serve(model, params, prompts, max_new, tmp_path,
                       monkeypatch, tuned_signature=sig_a, **RETUNE_KW)[1]
                for _ in range(2)]
        assert [e["step"] for e in runs[0].retunes] == \
            [e["step"] for e in runs[1].retunes]
        assert runs[0].retunes[0]["config"] == runs[1].retunes[0]["config"]
        assert runs[0].tokens == runs[1].tokens

    def test_winner_persists_under_its_signature(
            self, tiny_engine_parts, tmp_path, monkeypatch):
        from repro import autotune

        model, params, mcfg = tiny_engine_parts
        sig_a = _phase_a_sig(model, params, tmp_path, monkeypatch)
        prompts, max_new = _drift_workload()
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                           str(tmp_path / "persist.json"))
        autotune.reset_default_cache()
        try:
            from repro.serve import ServeConfig, ServeEngine

            eng = ServeEngine(model, params, ServeConfig(
                max_seq=48, batch_slots=8, kv_layout="paged", seed=0,
                prefill_chunk=8, slot_cap=3, tuned_signature=sig_a,
                **RETUNE_KW))
            res = eng.generate(prompts, max_new)
            assert len(res.retunes) == 1
            sig = res.retunes[0]["signature"]
            cands = autotune.serve_config_candidates(
                {"S": 48, "H": mcfg.padded_heads, "KV": mcfg.n_kv_heads,
                 "D": mcfg.head_dim_}, mcfg.compute_dtype)
            assert sig in cands
            entry = cands[sig]
            assert entry["config"] == res.retunes[0]["config"]
            assert entry["meta"]["source"] == "online_retune"
        finally:
            autotune.reset_default_cache()

    def test_slot_cap_caps_admission_not_tokens(
            self, tiny_engine_parts, tmp_path, monkeypatch):
        model, params, _ = tiny_engine_parts
        prompts, max_new = _drift_workload()
        _, capped = _serve(model, params, prompts, max_new, tmp_path,
                           monkeypatch, slot_cap=2)
        _, full = _serve(model, params, prompts, max_new, tmp_path,
                         monkeypatch, slot_cap=None)
        assert capped.tokens == full.tokens
        assert capped.steps > full.steps  # fewer slots, more passes


class TestAcceptanceRate:
    def _res(self, drafted, accepted):
        from repro.serve import GenerationResult

        return GenerationResult([], 0.0, 0.0, 0, drafted=drafted,
                                accepted=accepted)

    def test_no_drafts_is_nan_not_zero(self):
        assert math.isnan(self._res(0, 0).acceptance_rate)

    def test_all_rejected_is_zero(self):
        assert self._res(5, 0).acceptance_rate == 0.0

    def test_measured_ratio(self):
        assert self._res(8, 6).acceptance_rate == pytest.approx(0.75)


class TestBoundedDrafting:
    def test_tail_history_equals_suffix(self):
        from repro.serve.engine import _tail_history

        prompt, out = [1, 2, 3, 4, 5], [6, 7, 8]
        full = prompt + out
        for window in (1, 2, 3, 5, 7, 8, 100):
            assert _tail_history(prompt, out, window) == full[-window:]
        assert _tail_history(prompt, out, 0) == full
        assert _tail_history([], out, 2) == [7, 8]

    def test_windowed_draft_equals_draft_on_tail(self):
        from repro.serve import ServeEngine

        rng = np.random.default_rng(0)
        hist = rng.integers(0, 6, size=500).tolist()
        for window in (16, 64, 256):
            assert ServeEngine._ngram_draft(hist, 4, window=window) == \
                ServeEngine._ngram_draft(hist[-window:], 4)

    def test_draft_window_never_changes_tokens(
            self, tiny_engine_parts, tmp_path, monkeypatch):
        """The satellite's pin: the lookback bound changes WHAT gets
        drafted (dispatch counts), never what gets generated."""
        model, params, _ = tiny_engine_parts
        prompts, max_new = _drift_workload()
        outs = {}
        for window in (2, 24, 10 ** 6):
            _, res = _serve(model, params, prompts, max_new, tmp_path,
                            monkeypatch, draft_len=4, draft_window=window)
            outs[window] = res
        assert outs[2].tokens == outs[24].tokens == outs[10 ** 6].tokens

